"""Chaos-schedule fault harness (ft/chaos.py + ft/regrow.py, DESIGN.md
§14).

Fast in-process tests: the FaultSchedule DSL (byte-stable JSON round
trip, schema/kind/field rejection), ChaosInjector fire-once semantics,
checkpoint corruption detection (manifest digest + per-leaf sha256),
the growth planner's policy (mirror of the shrink planner), mb_split
numerics-neutrality, and ElasticSupervisor's regrow / NaN-rewind /
corrupt-skip / rebalance-with-hysteresis paths on the reference
Interpreter with bit-exact parity.

Soak subprocess (markers slow + chaos; CI job tier1-chaos): 8 faked
host XLA devices run the real SPMD executor through one scripted
kill -> regrow -> straggle -> rebalance -> corrupt -> NaN-spike
sequence; every fault recovers, steps-lost stays bounded by the
checkpoint interval per fault, and the final params match an
equivalent uninterrupted piecewise reference bit for bit in fp64.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from helpers import (inputs_spec, make_mlp_forward, make_mlp_params,
                     run_child_once_retry)

from repro.checkpoint import (CheckpointManager, CorruptCheckpointError,
                              load_manifest, reshard_tree)
from repro.core.compiler import compile_training
from repro.core.strategy import Mesh, Pipeline, Strategy, StrategyError, ZeRO
from repro.data import SyntheticVectorSource, VectorLoader
from repro.ft import (ChaosInjector, ChaosScheduleError, ElasticSupervisor,
                      FaultEvent, FaultSchedule, NumericalFailure,
                      RankFailure, RegrowthError, StragglerWatchdog,
                      WorkerFailure, check_numerics, corrupt_latest,
                      grow_for_arrivals, shrink_for_survivors, sgd_update,
                      zero_shard_degree)
from repro.runtime import Interpreter

_ROOT = pathlib.Path(__file__).resolve().parent.parent

S, D, BATCH = 4, 16, 8


def _bits(x) -> bytes:
    return np.asarray(x).tobytes()


def _params_bits(tree) -> list:
    return [_bits(l) for l in jax.tree_util.tree_leaves(tree)]


def _interp_factory(prog, params, devices):
    return Interpreter(prog, params=params, track_memory=False)


def _compile(sched="1f1b", zero=3, n_mb=2, mesh=None, mb_split=None,
             batch=BATCH):
    mesh = mesh or Mesh(pp=2, dp=2)
    strat = Strategy(mesh, Pipeline(sched, n_mb=n_mb, mb_split=mb_split)
                     | ZeRO(stage=zero)).validate()
    params = make_mlp_params(jax.random.PRNGKey(0), S, d=D)
    prog = compile_training(make_mlp_forward(S), params,
                            inputs_spec(batch, D), strategy=strat)
    return prog, params


def _demo_schedule():
    return FaultSchedule((
        FaultEvent(step=6, kind="kill", rank=3),
        FaultEvent(step=8, kind="arrive", devices=(3,)),
        FaultEvent(step=10, kind="straggle", rank=2, factor=3.0,
                   duration=12),
        FaultEvent(step=18, kind="corrupt", flips=4),
        FaultEvent(step=19, kind="nan_spike"),
    ), seed=7)


# ---------------------------------------------------------------------------
# the DSL
# ---------------------------------------------------------------------------

class TestFaultScheduleDSL:
    def test_json_round_trip_byte_stable(self):
        sched = _demo_schedule()
        doc = sched.to_json()
        again = FaultSchedule.from_json(doc)
        assert again == sched
        assert again.to_json() == doc
        # canonical encoding regardless of construction order
        shuffled = FaultSchedule(tuple(reversed(sched.events)), seed=7)
        assert shuffled.to_json() == doc

    def test_events_sorted_by_step(self):
        sched = _demo_schedule()
        assert [e.step for e in sched.events] == \
            sorted(e.step for e in sched.events)
        assert [e.step for e in sched.events_at(8)] == [8]

    def test_rejects_unknown_schema(self):
        with pytest.raises(ChaosScheduleError, match="schema"):
            FaultSchedule.from_json(
                '{"schema": 99, "seed": 0, "events": []}')

    def test_rejects_unknown_kind_and_field(self):
        with pytest.raises(ChaosScheduleError, match="unknown kind"):
            FaultSchedule.from_json(
                '{"schema": 1, "seed": 0, '
                '"events": [{"step": 1, "kind": "meteor"}]}')
        with pytest.raises(ChaosScheduleError, match="unknown field"):
            FaultSchedule.from_json(
                '{"schema": 1, "seed": 0, '
                '"events": [{"step": 1, "kind": "kill", "zap": 1}]}')

    def test_rejects_malformed_events(self):
        with pytest.raises(ChaosScheduleError, match="factor"):
            FaultEvent(step=1, kind="straggle", rank=0,
                       factor=0.5).validate()
        with pytest.raises(ChaosScheduleError, match="rank"):
            FaultEvent(step=1, kind="straggle", factor=2.0).validate()
        with pytest.raises(ChaosScheduleError, match="device"):
            FaultEvent(step=1, kind="arrive").validate()
        with pytest.raises(ChaosScheduleError, match="flips"):
            FaultEvent(step=1, kind="corrupt", flips=0).validate()

    def test_random_is_seed_deterministic(self):
        a = FaultSchedule.random(3, n_steps=20, world=8)
        b = FaultSchedule.random(3, n_steps=20, world=8)
        c = FaultSchedule.random(4, n_steps=20, world=8)
        assert a.to_json() == b.to_json()
        assert a.to_json() != c.to_json()


class TestChaosInjector:
    def test_kill_fires_once(self):
        inj = ChaosInjector(_demo_schedule())
        with pytest.raises(RankFailure) as ei:
            inj.check(6)
        assert ei.value.rank == 3 and ei.value.step == 6
        inj.check(6)      # replay through the same step: no re-raise

    def test_anonymous_kill(self):
        inj = ChaosInjector(FaultSchedule(
            (FaultEvent(step=2, kind="kill"),)))
        with pytest.raises(WorkerFailure):
            inj.check(2)

    def test_arrivals_report_once(self):
        inj = ChaosInjector(_demo_schedule())
        assert inj.arrivals(8) == [3]
        assert inj.arrivals(8) == []

    def test_straggle_windows_stateless(self):
        inj = ChaosInjector(_demo_schedule())
        for _ in range(2):    # replay sees the same slowdown
            assert inj.delay_factor(2, 10) == 3.0
            assert inj.delay_factor(2, 21) == 3.0
            assert inj.delay_factor(2, 22) == 1.0
            assert inj.delay_factor(1, 10) == 1.0

    def test_poison_and_corrupt_fire_once(self):
        inj = ChaosInjector(_demo_schedule())
        grads = {"w": np.ones(4)}
        out, poisoned = inj.poison_grads(19, grads)
        assert poisoned and np.isnan(np.asarray(out["w"])).all()
        _, again = inj.poison_grads(19, grads)
        assert not again
        assert [e.flips for e in inj.corruptions(18)] == [4]
        assert inj.corruptions(18) == []

    def test_sentinel_trips_on_nan_and_inf(self):
        check_numerics(0, 1.0, {"w": np.ones(3)})   # healthy: no raise
        with pytest.raises(NumericalFailure, match="loss"):
            check_numerics(1, float("nan"), {"w": np.ones(3)})
        with pytest.raises(NumericalFailure, match="gradient"):
            check_numerics(2, 1.0, {"w": np.array([1.0, np.inf])})

    def test_sentinel_trips_on_bf16_nan(self):
        # ml_dtypes customs register as numpy kind 'V', not 'f' — a
        # dtype.kind filter silently waved bf16 NaN grads through the
        # sentinel (found driving --chaos on a bf16 model end-to-end)
        healthy = {"w": jnp.ones(3, dtype=jnp.bfloat16)}
        check_numerics(0, 1.0, healthy)             # healthy: no raise
        poisoned = {"w": healthy["w"] * float("nan")}
        with pytest.raises(NumericalFailure, match="gradient"):
            check_numerics(1, 1.0, poisoned)


# ---------------------------------------------------------------------------
# checkpoint corruption detection
# ---------------------------------------------------------------------------

class TestCheckpointIntegrity:
    def _save_two(self, tmp_path):
        ckpt = CheckpointManager(tmp_path, keep=10, async_save=False)
        tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
                "b": np.ones(8, dtype=np.float32)}
        ckpt.save(2, tree, extra={"data": {"step": 2}})
        tree2 = {k: v + 1 for k, v in tree.items()}
        ckpt.save(4, tree2, extra={"data": {"step": 4}})
        return ckpt, tree, tree2

    def test_corrupt_latest_detected_and_skippable(self, tmp_path):
        ckpt, tree, _ = self._save_two(tmp_path)
        assert ckpt.verify(2) and ckpt.verify(4)
        step = corrupt_latest(ckpt, flips=4, seed=0)
        assert step == 4
        assert not ckpt.verify(4)
        assert ckpt.verify(2)          # older checkpoint untouched
        with pytest.raises(CorruptCheckpointError):
            ckpt.restore(tree, step=4)
        restored, extra = ckpt.restore(tree, step=2)
        assert extra["step"] == 2
        assert _params_bits(restored) == _params_bits(tree)

    def test_manifest_tamper_detected(self, tmp_path):
        ckpt, tree, _ = self._save_two(tmp_path)
        d = ckpt.step_dir(4)
        manifest = json.loads((d / "manifest.json").read_text())
        # forge a leaf hash: per-leaf sha256 would now pass, so only the
        # manifest content digest can catch it
        name = sorted(manifest["leaves"])[0]
        manifest["leaves"][name]["sha256"] = "0" * 64
        (d / "manifest.json").write_text(json.dumps(manifest, indent=1))
        with pytest.raises(CorruptCheckpointError, match="digest"):
            load_manifest(d)
        assert not ckpt.verify(4)

    def test_half_written_save_is_invisible(self, tmp_path):
        ckpt, _, _ = self._save_two(tmp_path)
        # a kill mid-save leaves only the .tmp staging dir — it must
        # never be listed, restored from, or garbage-collect anything
        tmp = ckpt.step_dir(6).with_suffix(".tmp")
        tmp.mkdir()
        (tmp / "leaf.npy").write_bytes(b"torn")
        assert ckpt.steps() == [2, 4]
        assert ckpt.latest_step() == 4

    def test_digest_covers_leaf_table(self, tmp_path):
        ckpt, _, _ = self._save_two(tmp_path)
        manifest = load_manifest(ckpt.step_dir(4))
        assert "digest" in manifest and len(manifest["digest"]) == 64


# ---------------------------------------------------------------------------
# growth planner
# ---------------------------------------------------------------------------

def _strategy(mesh, sched="1f1b", n_mb=4, zero=3, n_stages=None):
    return Strategy(mesh, Pipeline(sched, n_mb=n_mb, n_stages=n_stages)
                    | ZeRO(stage=zero)).validate()


class TestGrowthPlanner:
    def test_prefers_dp_growth(self):
        plan = grow_for_arrivals(_strategy(Mesh(pp=2, dp=1)), 4)
        assert plan.grown_axis == "dp"
        assert plan.new_mesh.shape == (2, 2)

    def test_largest_world_wins(self):
        plan = grow_for_arrivals(_strategy(Mesh(pp=2, dp=2)), 8)
        assert plan.new_mesh.n_devices == 8

    def test_pp_growth_requires_stage_divisibility(self):
        # 4 stages pinned (2 per rank under pp=2): pp can grow to 4
        # (1 stage per rank) but never to 3
        strat = _strategy(Mesh(pp=2, dp=1), n_stages=4)
        plan = grow_for_arrivals(strat, 4)
        # dp growth is preferred at equal world; growing dp to 4 fits
        assert plan.new_mesh.n_devices == 4
        assert plan.grown_axis == "dp"
        # with dp maxed away, pp=3 (12 ranks would fit 3x4) is invalid:
        # 4 stages % 3 != 0 — the only valid pp target is 4
        strat_pp = Strategy(Mesh(pp=2), Pipeline("1f1b", n_mb=4,
                                                 n_stages=4)).validate()
        plan_pp = grow_for_arrivals(strat_pp, 5)
        assert plan_pp.grown_axis == "pp"
        assert plan_pp.new_mesh["pp"] == 4      # 3 was skipped

    def test_shrink_then_grow_restores_original_mesh(self):
        strat = _strategy(Mesh(pp=2, dp=2))
        shrunk = shrink_for_survivors(strat, range(3))
        regrown = grow_for_arrivals(shrunk.strategy, 4)
        assert regrown.new_mesh.axis_names == strat.mesh.axis_names
        assert regrown.new_mesh.shape == strat.mesh.shape
        # and the regrown strategy drops any stale rebalance split
        assert regrown.strategy.pipeline.mb_split is None

    def test_errors(self):
        with pytest.raises(RegrowthError, match="nothing to grow"):
            grow_for_arrivals(_strategy(Mesh(pp=2, dp=2)), 4)
        with pytest.raises(RegrowthError, match="no valid grown mesh"):
            # the only growable axis is pp, and 3 pinned stages divide
            # neither 4 nor 5 — every candidate fails for_mesh
            grow_for_arrivals(
                Strategy(Mesh(pp=3), Pipeline("1f1b", n_mb=4,
                                              n_stages=3)).validate(), 5)


# ---------------------------------------------------------------------------
# mb_split: scheduling metadata, bit-identical numerics
# ---------------------------------------------------------------------------

class TestMbSplitNumerics:
    def test_meta_recorded_and_bit_identical(self):
        split = {0: 3, 1: 3, 2: 0, 3: 2}
        prog_plain, params = _compile(n_mb=8, batch=16)
        prog_split, _ = _compile(n_mb=8, mb_split=split, batch=16)
        assert prog_plain.dag.meta.get("mb_split") is None
        assert prog_split.dag.meta["mb_split"] == split
        loader = VectorLoader(SyntheticVectorSource(D, seed=5),
                              batch=16)
        batch = loader.next_batch()
        a = Interpreter(prog_plain, params=params,
                        track_memory=False).run(batch)
        b = Interpreter(prog_split, params=params,
                        track_memory=False).run(batch)
        assert _bits(np.float64(float(a.loss))) == \
            _bits(np.float64(float(b.loss)))
        assert _params_bits(a.grads) == _params_bits(b.grads)

    def test_validate_rejects_bad_splits(self):
        for bad in (((0, 4), (0, 4)), {0: 4, 9: 4}, {0: -1, 1: 9},
                    {0: 2, 1: 2, 2: 2, 3: 1}):
            with pytest.raises(StrategyError, match="mb_split"):
                _compile(n_mb=8, mb_split=bad)

    def test_for_mesh_drops_split(self):
        strat = Strategy(Mesh(pp=2, dp=2),
                         Pipeline("1f1b", n_mb=8,
                                  mb_split={0: 2, 1: 2, 2: 2, 3: 2})
                         | ZeRO(stage=3)).validate()
        shrunk = shrink_for_survivors(strat, range(3))
        assert shrunk.strategy.pipeline.mb_split is None


# ---------------------------------------------------------------------------
# supervisor chaos paths (fast, reference Interpreter)
# ---------------------------------------------------------------------------

class TestSupervisorChaos:
    def _loader(self, seed=7):
        return VectorLoader(SyntheticVectorSource(D, seed=seed),
                            batch=BATCH)

    def _sup(self, tmp_path, schedule, *, every=2, n_mb=2, **kw):
        prog, params = _compile(n_mb=n_mb)
        ckpt = CheckpointManager(tmp_path, keep=10, async_save=False)
        sup = ElasticSupervisor(
            prog, ckpt, self._loader(), runner_factory=_interp_factory,
            checkpoint_every=every,
            injector=ChaosInjector(schedule) if schedule else None, **kw)
        return prog, params, sup, ckpt

    def test_kill_then_regrow_restores_mesh_bitexact(self, tmp_path):
        sched = FaultSchedule((
            FaultEvent(step=3, kind="kill", rank=3),
            FaultEvent(step=5, kind="arrive", devices=(3,)),
        ))
        prog, params, sup, ckpt = self._sup(tmp_path, sched)
        final = sup.run(params, 10, log_every=0)

        # shrink accounting
        assert len(sup.reports) == 1
        r = sup.reports[0]
        assert r.resume_step == 2 and r.steps_lost == 1
        assert r.old_world == 4 and r.new_world == 2
        # regrowth restored the ORIGINAL mesh shape with zero lost steps
        assert len(sup.growths) == 1
        g = sup.growths[0]
        assert g.step == 5 and g.steps_lost == 0
        assert g.old_world == 2 and g.new_world == 4
        assert sup.strategy.mesh.shape == prog.strategy.mesh.shape
        assert sup.world == 4 and sorted(sup.physical) == [0, 1, 2, 3]
        assert sup.standby == []

        # piecewise parity: original 0..2, shrunk 2..5 (reshard down),
        # regrown 5..10 (reshard up) — bit-exact in fp64
        plan = shrink_for_survivors(prog.strategy, range(3))
        gplan = grow_for_arrivals(plan.strategy, 4)
        update = sgd_update()
        loader = self._loader()
        p = params
        it = Interpreter(prog, params=p, track_memory=False)
        ref = {}
        for step in range(10):
            if step == 2:
                state, extra = ckpt.restore({"params": p}, step=2)
                p = state["params"]
                loader.load_state_dict(extra["data"])
                p = reshard_tree(p, int(extra["zero_shards"]),
                                 zero_shard_degree(plan.strategy))
                it = Interpreter(prog.recompile(strategy=plan.strategy),
                                 params=p, track_memory=False)
            if step == 5:
                p = reshard_tree(p, zero_shard_degree(plan.strategy),
                                 zero_shard_degree(gplan.strategy))
                it = Interpreter(prog.recompile(strategy=gplan.strategy),
                                 params=p, track_memory=False)
            res = it.run(loader.next_batch())
            p = update(p, res.grads, step)
            it.params = p
            ref[step + 1] = float(res.loss)
        got = {h["step"]: h["loss"] for h in sup.history}  # last wins
        for step, want in ref.items():
            assert _bits(np.float64(got[step])) == \
                _bits(np.float64(want)), f"loss diverged at {step}"
        assert _params_bits(final) == _params_bits(p)

    def test_arrival_without_valid_mesh_banks_standby(self, tmp_path):
        # a lone arrival on a full world cannot grow (no axis increase
        # fits 5 ranks over pp2 x dp2) — it must be banked, not crash
        sched = FaultSchedule((
            FaultEvent(step=2, kind="arrive", devices=(4,)),))
        _, params, sup, _ = self._sup(tmp_path, sched)
        sup.run(params, 4, log_every=0)
        assert sup.growths == []
        assert sup.standby == [4]
        assert sup.world == 4

    def test_nan_spike_rewinds_and_matches_fault_free_run(self, tmp_path):
        sched = FaultSchedule((FaultEvent(step=5, kind="nan_spike"),))
        _, params, sup, _ = self._sup(tmp_path, sched)
        final = sup.run(params, 8, log_every=0)
        assert sup.numeric_rewinds == 1
        assert len(sup.reports) == 1
        r = sup.reports[0]
        assert r.step_failed == 5 and r.resume_step == 4
        assert r.steps_lost == 1        # bounded by the ckpt interval
        assert r.old_world == r.new_world == 4   # rewind-only: no shrink
        assert all(np.isfinite(np.asarray(l)).all()
                   for l in jax.tree_util.tree_leaves(final))

        # the poisoned update never touched the weights, so the final
        # params are bit-identical to a run with no fault at all
        prog2, params2 = _compile()
        loader2 = self._loader()
        update = sgd_update()
        it = Interpreter(prog2, params=params2, track_memory=False)
        p = params2
        for step in range(8):
            res = it.run(loader2.next_batch())
            p = update(p, res.grads, step)
            it.params = p
        assert _params_bits(final) == _params_bits(p)

    def test_corrupt_checkpoint_skipped_on_recovery(self, tmp_path):
        sched = FaultSchedule((
            FaultEvent(step=4, kind="corrupt", flips=6),
            FaultEvent(step=5, kind="kill", rank=3),
        ))
        _, params, sup, ckpt = self._sup(tmp_path, sched)
        sup.run(params, 8, log_every=0)
        # the corrupted step-4 checkpoint was detected and skipped; the
        # recovery restored step 2 instead
        assert sup.corrupt_detected == 1
        assert sup.corrupt_skipped_steps == [4]
        assert sup.reports[0].resume_step == 2
        assert sup.reports[0].steps_lost == 3   # <= 2 intervals: 2 faults
        # the replay re-saved a GOOD checkpoint over the corrupt one
        # (the corrupt event fired once and does not replay)
        assert ckpt.verify(4)

    def test_all_checkpoints_corrupt_falls_back_to_pristine(
            self, tmp_path):
        sched = FaultSchedule((
            FaultEvent(step=3, kind="corrupt", flips=6),
            FaultEvent(step=4, kind="kill", rank=3),
        ))
        prog, params, sup, ckpt = self._sup(tmp_path, sched, every=3)
        sup.run(params, 6, log_every=0)
        # only checkpoint (step 3) was corrupt -> from-scratch restart
        assert sup.corrupt_detected == 1
        assert sup.reports[0].resume_step == 0
        assert sup.reports[0].steps_lost == 4

    def test_chaos_report_accounting(self, tmp_path):
        sched = FaultSchedule((
            FaultEvent(step=3, kind="kill", rank=3),
            FaultEvent(step=5, kind="arrive", devices=(3,)),
        ), seed=11)
        _, params, sup, _ = self._sup(tmp_path, sched)
        sup.run(params, 10, log_every=0)
        rep = sup.chaos_report(10, wall_seconds=1.0)
        assert rep.schedule_seed == 11 and rep.n_events == 2
        assert rep.kinds == {"kill": 1, "arrive": 1}
        assert len(rep.recoveries) == 1 and len(rep.growths) == 1
        assert rep.steps_lost_total == 1
        assert rep.final_world == 4
        doc = json.loads(rep.to_json())
        assert doc["growths"][0]["new_world"] == 4


class TestRebalanceRecompile:
    def _run(self, tmp_path, schedule, *, rebalance=True, seed=7,
             n_steps=12, n_mb=8, **kw):
        prog, params = _compile(n_mb=n_mb, batch=16)
        loader = VectorLoader(SyntheticVectorSource(D, seed=seed),
                              batch=16)
        ckpt = CheckpointManager(tmp_path, keep=10, async_save=False)
        sup = ElasticSupervisor(
            prog, ckpt, loader, runner_factory=_interp_factory,
            checkpoint_every=2,
            injector=ChaosInjector(schedule) if schedule else None,
            rebalance=rebalance, **kw)
        final = sup.run(params, n_steps, log_every=0)
        return sup, final

    def test_persistent_straggler_triggers_one_rebalance(self, tmp_path):
        # rank 2 runs exactly 4x slow from step 0: every per-rank EMA is
        # the SAME weighted sum scaled by the factor, so slowdowns() is
        # exactly {.., 2: 4.0, ..} at every boundary -> the proposal is
        # identical each time and hysteresis fires after `patience`
        sched = FaultSchedule((
            FaultEvent(step=0, kind="straggle", rank=2, factor=4.0,
                       duration=100),))
        sup, _ = self._run(tmp_path, sched, rebalance_patience=2,
                           rebalance_cooldown=2)
        assert len(sup.rebalances) == 1
        rb = sup.rebalances[0]
        # boundaries at 2 (streak 1) and 4 (streak 2 -> act)
        assert rb.step == 4
        assert sum(rb.split.values()) == 8
        assert rb.split[2] == min(rb.split.values())
        assert sup.strategy.pipeline.mb_split_dict() == rb.split
        # once applied, the unchanged proposal never re-fires, and the
        # rebalanced strategy advertises itself in its label
        assert "/rb" in sup.strategy.label()

    def test_rebalance_is_numerics_neutral(self, tmp_path):
        sched = FaultSchedule((
            FaultEvent(step=0, kind="straggle", rank=2, factor=4.0,
                       duration=100),))
        sup, final = self._run(tmp_path, sched, rebalance_patience=2,
                               rebalance_cooldown=2)
        assert sup.rebalances        # the recompile really happened
        sup2, final2 = self._run(tmp_path / "ref", None, rebalance=False)
        got = {h["step"]: h["loss"] for h in sup.history}
        want = {h["step"]: h["loss"] for h in sup2.history}
        assert got.keys() == want.keys()
        for step in want:
            assert _bits(np.float64(got[step])) == \
                _bits(np.float64(want[step])), step
        assert _params_bits(final) == _params_bits(final2)

    def test_oscillating_emas_never_thrash(self, tmp_path):
        class Oscillating(StragglerWatchdog):
            def __init__(self):
                super().__init__()
                self.calls = 0

            def slowdowns(self):
                self.calls += 1
                return ({0: 3.0, 1: 1.0, 2: 1.0, 3: 1.0}
                        if self.calls % 2 else
                        {0: 1.0, 1: 1.0, 2: 3.0, 3: 1.0})

        wd = Oscillating()
        sup, _ = self._run(tmp_path, None, watchdog=wd,
                           rebalance_patience=2, rebalance_cooldown=2)
        assert wd.calls >= 4            # proposals were consulted
        assert sup.rebalances == []     # but never acted on

    def test_cooldown_blocks_repeat_recompiles(self, tmp_path):
        class Shifting(StragglerWatchdog):
            """A different persistent straggler after every boundary —
            without a cooldown this would recompile at every one."""
            def __init__(self):
                super().__init__()
                self.calls = 0

            def slowdowns(self):
                self.calls += 1
                slow = (self.calls // 3) % 4
                d = {r: 1.0 for r in range(4)}
                d[slow] = 4.0
                return d

        sup, _ = self._run(tmp_path, None, watchdog=Shifting(),
                           rebalance_patience=1,
                           rebalance_cooldown=100, n_steps=12)
        assert len(sup.rebalances) == 1

    def test_uniform_fleet_never_rebalances(self, tmp_path):
        sup, _ = self._run(tmp_path, None, rebalance_patience=1,
                           rebalance_cooldown=0)
        assert sup.rebalances == []
        assert sup.strategy.pipeline.mb_split is None

    def test_canonical_split_is_on_pace_when_nmb_lt_world(self,
                                                          tmp_path):
        # n_mb=2 over 4 ranks: the canonical healthy split {1,1,0,0}
        # has unequal counts — a healthy fleet must still never
        # rebalance (regression: "all counts equal" is the wrong
        # uniformity test)
        class Healthy(StragglerWatchdog):
            def slowdowns(self):
                return {r: 1.0 for r in range(4)}

        sup, _ = self._run(tmp_path, None, watchdog=Healthy(), n_mb=2,
                           rebalance_patience=1, rebalance_cooldown=0)
        assert sup.rebalances == []
        assert sup.strategy.pipeline.mb_split is None

    def test_recovered_fleet_reverts_split(self, tmp_path):
        # skewed for two boundaries (apply a split), then back on pace:
        # the supervisor must recompile the default schedule back in —
        # under the same hysteresis, so one noisy boundary cannot flap
        class Recovering(StragglerWatchdog):
            def __init__(self):
                super().__init__()
                self.calls = 0

            def slowdowns(self):
                self.calls += 1
                if self.calls <= 2:
                    return {0: 1.0, 1: 1.0, 2: 4.0, 3: 1.0}
                return {r: 1.0 for r in range(4)}

        sup, _ = self._run(tmp_path, None, watchdog=Recovering(),
                           rebalance_patience=2, rebalance_cooldown=2)
        assert len(sup.rebalances) == 2
        apply, revert = sup.rebalances
        assert apply.step == 4 and sum(apply.split.values()) == 8
        assert revert.step == 8 and revert.split == {}
        assert sup.strategy.pipeline.mb_split is None
        assert "/rb" not in sup.strategy.label()


# ---------------------------------------------------------------------------
# the soak: scripted kill -> regrow -> straggle -> rebalance -> corrupt
# -> NaN on 8 faked XLA devices (markers slow + chaos; CI tier1-chaos)
# ---------------------------------------------------------------------------

CHILD_SOAK = r"""
import json, os, pathlib, sys, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np

from helpers import inputs_spec, make_mlp_forward, make_mlp_params
from repro.checkpoint import CheckpointManager, reshard_tree
from repro.core.compiler import compile_training
from repro.core.strategy import Mesh, Pipeline, Strategy, ZeRO
from repro.data import SyntheticVectorSource, VectorLoader
from repro.ft import (ChaosInjector, ElasticSupervisor, FaultEvent,
                      FaultSchedule, grow_for_arrivals,
                      shrink_for_survivors, sgd_update,
                      zero_shard_degree)
from repro.runtime.spmd import SpmdExecutor

S, D, BATCH = 8, 16, 16
N_STEPS, CKPT = 24, 4

def bits(x):
    return np.asarray(x).tobytes()

def params_bits(tree):
    return [bits(l) for l in jax.tree_util.tree_leaves(tree)]

def spmd_factory(prog, params, devices):
    return SpmdExecutor(prog, params=params, physical_devices=devices)

schedule = FaultSchedule((
    FaultEvent(step=6, kind="kill", rank=3),
    FaultEvent(step=8, kind="arrive", devices=(3,)),
    # from step 8 (the regrowth boundary, where rank EMAs reset) rank 2
    # runs exactly 3x slow: slowdowns() is exactly 3.0 every boundary,
    # so the rebalance proposal is stable and hysteresis fires at the
    # second boundary (step 16)
    FaultEvent(step=8, kind="straggle", rank=2, factor=3.0, duration=16),
    FaultEvent(step=16, kind="corrupt", flips=8),
    FaultEvent(step=19, kind="nan_spike"),
), seed=23)
doc = schedule.to_json()
assert FaultSchedule.from_json(doc).to_json() == doc

mesh = Mesh(pp=4, dp=2)
strat = Strategy(mesh, Pipeline("1f1b", n_mb=4)
                 | ZeRO(stage=3)).validate()
params = make_mlp_params(jax.random.PRNGKey(0), S, d=D)
prog = compile_training(make_mlp_forward(S), params,
                        inputs_spec(BATCH, D), strategy=strat)

with tempfile.TemporaryDirectory() as td:
    loader = VectorLoader(SyntheticVectorSource(D, seed=11), batch=BATCH)
    ckpt = CheckpointManager(pathlib.Path(td), keep=10, async_save=False)
    sup = ElasticSupervisor(
        prog, ckpt, loader, runner_factory=spmd_factory,
        checkpoint_every=CKPT, injector=ChaosInjector(schedule),
        rebalance=True, rebalance_patience=2, rebalance_cooldown=CKPT)
    final = sup.run(params, N_STEPS, log_every=0)

    # --- every fault recovered, with bounded steps-lost ---------------
    # kill at 6 -> shrink dp, resume at checkpoint 4
    shrinks = [r for r in sup.reports if r.shrunk_axis]
    assert len(shrinks) == 1, sup.reports
    k = shrinks[0]
    assert k.step_failed == 6 and k.resume_step == 4
    assert 0 < k.steps_lost <= CKPT
    assert k.old_world == 8 and k.new_world == 4
    assert k.failed_rank == 3 and k.shrunk_axis == "dp"

    # arrival at 8 -> regrowth restores the ORIGINAL mesh, 0 lost steps
    assert len(sup.growths) == 1, sup.growths
    g = sup.growths[0]
    assert g.step == 8 and g.steps_lost == 0
    assert g.old_world == 4 and g.new_world == 8
    assert g.grown_axis == "dp"
    assert sup.strategy.mesh.shape == mesh.shape
    assert sup.strategy.mesh.axis_names == mesh.axis_names
    assert 3 not in sup.physical[:4]     # dead chip replaced, not reused
    assert sorted(sup.physical) == list(range(8))

    # straggler detected -> exactly one rebalance recompile at step 16
    assert len(sup.rebalances) == 1, sup.rebalances
    rb = sup.rebalances[0]
    assert rb.step == 16
    assert sum(rb.split.values()) == 4
    assert rb.split[2] == min(rb.split.values())
    assert abs(rb.slowdowns[2] - 3.0) < 1e-6, rb.slowdowns

    # corrupt checkpoint detected and skipped; NaN spike rewound to the
    # newest GOOD checkpoint (12, not the corrupted 16)
    assert sup.corrupt_detected == 1
    assert sup.corrupt_skipped_steps == [16]
    rewinds = [r for r in sup.reports if not r.shrunk_axis]
    assert len(rewinds) == 1 and sup.numeric_rewinds == 1
    n = rewinds[0]
    assert n.step_failed == 19 and n.resume_step == 12
    # two stacked faults (corrupt + nan) cost at most two intervals
    assert n.steps_lost <= 2 * CKPT

    # --- fp64 bit-parity vs the equivalent uninterrupted reference ----
    # original program 0..4, shrunk program 4..8 from the shared
    # checkpoint (ZeRO reshard down), regrown(=original-shape) program
    # 8..24 (ZeRO reshard up).  Straggle windows, the mb_split
    # recompile and the NaN rewind replay are all numerics-neutral, so
    # this covers the whole soak.
    plan = shrink_for_survivors(strat, [r for r in range(8) if r != 3])
    gplan = grow_for_arrivals(plan.strategy, 8)
    update = sgd_update()
    rl = VectorLoader(SyntheticVectorSource(D, seed=11), batch=BATCH)
    p = params
    ex = SpmdExecutor(prog, params=p)
    ref = {}
    for step in range(N_STEPS):
        if step == 4:
            state, extra = ckpt.restore({"params": p}, step=4)
            p = state["params"]
            rl.load_state_dict(extra["data"])
            p = reshard_tree(p, int(extra["zero_shards"]),
                             zero_shard_degree(plan.strategy))
            ex = SpmdExecutor(prog.recompile(strategy=plan.strategy),
                              params=p)
        if step == 8:
            p = reshard_tree(p, zero_shard_degree(plan.strategy),
                             zero_shard_degree(gplan.strategy))
            ex = SpmdExecutor(prog.recompile(strategy=gplan.strategy),
                              params=p)
        res = ex.run(rl.next_batch())
        p = update(p, res.grads, step)
        ex.params = p
        ref[step + 1] = float(res.loss)

    got = {h["step"]: h["loss"] for h in sup.history}   # last wins
    for step, want in ref.items():
        assert bits(np.float64(got[step])) == bits(np.float64(want)), \
            (step, got[step], want)
    assert params_bits(final) == params_bits(p)

    # ChaosReport serializes the whole story
    rep = sup.chaos_report(N_STEPS)
    out = json.loads(rep.to_json())
    assert out["kinds"] == {"kill": 1, "arrive": 1, "straggle": 1,
                            "corrupt": 1, "nan_spike": 1}
    assert out["final_world"] == 8
    assert out["steps_lost_total"] == k.steps_lost + n.steps_lost

print("SOAK_OK", flush=True)
"""


@pytest.mark.slow
@pytest.mark.chaos
class TestChaosSoak:
    """One scripted kill -> regrow -> straggle -> rebalance -> corrupt
    -> NaN sequence end to end on 8 faked XLA devices (subprocess: the
    device-count flag must be set before jax initializes)."""

    def test_soak_sequence(self):
        out = run_child_once_retry(CHILD_SOAK, "{}", timeout=600)
        assert "SOAK_OK" in out, out
