"""Elastic fault tolerance (ft/elastic.py, DESIGN.md §13).

Fast in-process tests: mesh-shrink planner policy, rank-failure
injection, straggler watchdog -> microbatch rebalance hook, and a full
elastic recovery loop on the reference Interpreter with bit-exact
resume parity.

Kill-a-rank subprocess grid (markers slow + elastic; CI job
tier1-elastic): 8 faked host XLA devices run the real SPMD executor,
one rank dies mid-run, the supervisor shrinks the mesh / recompiles /
restores the checkpoint + stream position / resumes on the surviving
devices — and the resumed run must match an uninterrupted run that
restored the same checkpoint onto the same shrunk mesh, bit for bit in
fp64, across {1f1b, gpipe} x ZeRO{0, 3}.
"""
import json
import pathlib

import jax
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from helpers import inputs_spec, make_mlp_forward, make_mlp_params

from repro.checkpoint import CheckpointManager
from repro.core.compiler import compile_training
from repro.core.strategy import Mesh, Pipeline, Strategy, ZeRO
from repro.data import SyntheticVectorSource, VectorLoader
from repro.ft import (ElasticError, ElasticSupervisor, RankFailure,
                      RankFailureInjector, StragglerWatchdog,
                      shrink_for_survivors, sgd_update, zero_shard_degree)
from repro.runtime import Interpreter
from repro.tune import rebalance_microbatches

_ROOT = pathlib.Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# mesh-shrink planner
# ---------------------------------------------------------------------------

class TestShrinkPlanner:
    def _strat(self, sched="1f1b", pp=4, dp=2, zero=3):
        mesh = Mesh(pp=pp, dp=dp)
        return Strategy(mesh, Pipeline(sched, n_mb=4)
                        | ZeRO(stage=zero)).validate()

    def test_prefers_dp_shrink(self):
        plan = shrink_for_survivors(self._strat(), range(7))
        assert plan.shrunk_axis == "dp"
        assert plan.new_mesh == Mesh(pp=4, dp=1)
        assert plan.strategy.mesh == plan.new_mesh

    def test_largest_world_wins(self):
        # 6 survivors: dp 2->1 (world 4) beats any pp shrink (pp=2 also
        # world 4 but dp is preferred; pp=1 is world 2)
        plan = shrink_for_survivors(self._strat(), range(6))
        assert plan.new_mesh.n_devices == 4
        assert plan.shrunk_axis == "dp"

    def test_pp_shrink_requires_stage_divisibility(self):
        # S is pinned to 8 (2 * pp under the OLD mesh): pp'=3 invalid
        # (8 % 3), pp'=2 valid -> with 3 survivors the best is pp=1,dp=2
        plan = shrink_for_survivors(self._strat(), range(3))
        assert plan.shrunk_axis == "pp"
        assert plan.new_mesh == Mesh(pp=1, dp=2)
        # stage count is pinned, so 8 stages now live on 1 rank
        assert plan.strategy.pipeline.n_stages == 8

    def test_plan_depends_only_on_survivor_count(self):
        a = shrink_for_survivors(self._strat(), [0, 1, 2, 3, 4, 5, 6])
        b = shrink_for_survivors(self._strat(), [1, 2, 3, 4, 5, 6, 7])
        assert a.new_mesh == b.new_mesh and a.shrunk_axis == b.shrunk_axis

    def test_dualpipev_cannot_shrink_pp(self):
        # dualpipev pins S == 2*pp; S is pinned to the old value, so any
        # pp' != pp is invalid and only dp can shrink
        strat = self._strat(sched="dualpipev")
        plan = shrink_for_survivors(strat, range(7))
        assert plan.shrunk_axis == "dp"
        with pytest.raises(ElasticError):
            # dp already 1 after one shrink; only pp reductions remain,
            # all invalid for dualpipev
            shrink_for_survivors(plan.strategy, range(3))

    def test_errors(self):
        strat = self._strat()
        with pytest.raises(ElasticError):
            shrink_for_survivors(strat, [])
        with pytest.raises(ElasticError):  # nothing to shrink
            shrink_for_survivors(strat, range(8))

    def test_zero_shard_degree(self):
        assert zero_shard_degree(self._strat(zero=3)) == 2
        assert zero_shard_degree(self._strat(zero=2)) == 2
        assert zero_shard_degree(self._strat(zero=1)) == 1
        assert zero_shard_degree(self._strat(zero=0)) == 1


class TestRankFailureInjector:
    def test_fires_once_with_rank(self):
        inj = RankFailureInjector({3: 1})
        inj.check(2)
        with pytest.raises(RankFailure) as ei:
            inj.check(3)
        assert ei.value.rank == 1 and ei.value.step == 3
        inj.check(3)  # second pass: already fired


# ---------------------------------------------------------------------------
# straggler watchdog -> microbatch rebalance
# ---------------------------------------------------------------------------

class TestWatchdogRebalance:
    def test_no_false_positive_on_uniform_trace(self):
        wd = StragglerWatchdog(threshold=2.0)
        rng = np.random.default_rng(0)
        flagged = []
        for step in range(50):
            for rank in range(4):
                # +-5% jitter around a common step time
                dt = 0.1 * (1 + 0.05 * rng.standard_normal())
                if wd.observe_rank(rank, step, dt):
                    flagged.append((step, rank))
        assert flagged == []
        assert wd.rank_events == []
        slow = wd.slowdowns()
        assert set(slow) == {0, 1, 2, 3}
        assert all(abs(v - 1.0) < 0.2 for v in slow.values())

    def test_detects_persistent_straggler(self):
        wd = StragglerWatchdog(threshold=2.0)
        for step in range(20):
            for rank in range(4):
                wd.observe_rank(rank, step, 0.3 if rank == 2 else 0.1)
        assert any(rank == 2 for (_, rank, _, _) in wd.rank_events)
        assert all(rank == 2 for (_, rank, _, _) in wd.rank_events)
        slow = wd.slowdowns()
        assert slow[2] > 2.5
        assert abs(slow[0] - 1.0) < 0.05

    def test_ema_feeds_rebalance(self):
        wd = StragglerWatchdog()
        for step in range(20):
            for rank in range(4):
                wd.observe_rank(rank, step, 0.3 if rank == 2 else 0.1)
        counts = rebalance_microbatches(8, wd.slowdowns())
        assert sum(counts.values()) == 8
        # the 3x straggler gets the smallest share
        assert counts[2] == min(counts.values())
        assert counts[2] < counts[0]

    def test_rebalance_uniform_guard(self):
        # within-threshold spread -> exactly uniform split
        assert rebalance_microbatches(8, {0: 1.0, 1: 1.1, 2: 0.95,
                                          3: 1.05}) == \
            {0: 2, 1: 2, 2: 2, 3: 2}
        # remainder goes to the fastest ranks
        counts = rebalance_microbatches(7, {0: 1.0, 1: 1.1, 2: 0.95})
        assert sum(counts.values()) == 7
        assert counts[2] == 3  # fastest
        assert counts[1] == 2

    def test_rebalance_proportional(self):
        counts = rebalance_microbatches(12, {0: 1.0, 1: 2.0})
        assert sum(counts.values()) == 12
        assert counts[0] == 8 and counts[1] == 4  # 2:1 speed ratio

    def test_rebalance_errors(self):
        with pytest.raises(ValueError):
            rebalance_microbatches(4, {})
        with pytest.raises(ValueError):
            rebalance_microbatches(4, {0: 0.0})
        with pytest.raises(ValueError):
            rebalance_microbatches(-1, {0: 1.0})


# ---------------------------------------------------------------------------
# fast in-process elastic recovery (reference Interpreter)
# ---------------------------------------------------------------------------

S, D, BATCH = 4, 16, 8


def _interp_factory(prog, params, devices):
    # the Interpreter simulates devices; physical mapping is a no-op
    return Interpreter(prog, params=params, track_memory=False)


def _compile(sched="1f1b", zero=3, n_mb=2):
    mesh = Mesh(pp=2, dp=2)
    strat = Strategy(mesh, Pipeline(sched, n_mb=n_mb)
                     | ZeRO(stage=zero)).validate()
    params = make_mlp_params(jax.random.PRNGKey(0), S, d=D)
    prog = compile_training(make_mlp_forward(S), params,
                            inputs_spec(BATCH, D), strategy=strat)
    return prog, params


def _bits(x) -> bytes:
    return np.asarray(x).tobytes()


def _params_bits(tree) -> list:
    return [_bits(l) for l in jax.tree_util.tree_leaves(tree)]


class TestElasticSupervisorFast:
    def _run_elastic(self, tmp_path, *, fail_at=5, rank=3, n_steps=8,
                     every=3, seed=7):
        prog, params = _compile()
        loader = VectorLoader(SyntheticVectorSource(D, seed=seed),
                              batch=BATCH)
        ckpt = CheckpointManager(tmp_path, keep=10, async_save=False)
        sup = ElasticSupervisor(
            prog, ckpt, loader, runner_factory=_interp_factory,
            checkpoint_every=every,
            injector=RankFailureInjector({fail_at: rank}))
        final = sup.run(params, n_steps, log_every=0)
        return prog, params, sup, final, ckpt

    def test_recovery_report_accounting(self, tmp_path):
        _, _, sup, _, _ = self._run_elastic(tmp_path)
        assert len(sup.reports) == 1
        r = sup.reports[0]
        assert r.step_failed == 5 and r.resume_step == 3
        assert r.steps_lost == 2          # bounded by the ckpt interval
        assert r.old_world == 4 and r.new_world == 2
        assert r.failed_rank == 3 and r.shrunk_axis == "dp"
        assert not r.cache_hit
        assert r.recovery_seconds >= r.compile_seconds >= 0
        # post-recovery steps ran on the shrunk world
        worlds = {h["step"]: h["world"] for h in sup.history}
        assert worlds[3] == 4 and worlds[8] == 2

    def test_resume_parity_bitexact_vs_uninterrupted(self, tmp_path):
        prog, params, sup, final, ckpt = self._run_elastic(tmp_path)
        # reference: restore the SAME checkpoint, run the SAME shrunk
        # program uninterrupted — identical restored state + identical
        # program => bit-identical losses and params from step 4 on
        plan = shrink_for_survivors(prog.strategy, [0, 1, 2])
        ref_prog = prog.recompile(strategy=plan.strategy)
        state, extra = ckpt.restore({"params": params}, step=3)
        loader = VectorLoader(SyntheticVectorSource(D, seed=7),
                              batch=BATCH)
        loader.load_state_dict(extra["data"])
        p = state["params"]
        if int(extra["zero_shards"]) != zero_shard_degree(plan.strategy):
            from repro.checkpoint import reshard_tree
            p = reshard_tree(p, int(extra["zero_shards"]),
                             zero_shard_degree(plan.strategy))
        update = sgd_update()
        it = Interpreter(ref_prog, params=p, track_memory=False)
        ref_losses = {}
        for step in range(3, 8):
            res = it.run(loader.next_batch())
            p = update(p, res.grads, step)
            it.params = p
            ref_losses[step + 1] = float(res.loss)
        got = {h["step"]: h["loss"] for h in sup.history}  # last wins
        for step, ref in ref_losses.items():
            assert _bits(np.float64(got[step])) == \
                _bits(np.float64(ref)), f"loss diverged at step {step}"
        assert _params_bits(final) == _params_bits(p)

    def test_failure_before_first_checkpoint_rewinds_stream(
            self, tmp_path):
        prog, params = _compile()
        loader = VectorLoader(SyntheticVectorSource(D, seed=3),
                              batch=BATCH)
        pristine = loader.fingerprint()
        ckpt = CheckpointManager(tmp_path, keep=4, async_save=False)
        sup = ElasticSupervisor(
            prog, ckpt, loader, runner_factory=_interp_factory,
            checkpoint_every=100,   # no checkpoint before the failure
            injector=RankFailureInjector({2: 3}))
        sup.run(params, 4, log_every=0)
        r = sup.reports[0]
        assert r.resume_step == 0 and r.steps_lost == 2
        # the restart consumed the stream from its pristine position:
        # 4 completed steps from a rewound loader leave it at step 4
        assert int(loader.state_dict()["step"]) == 4
        # and the shrunk-world restart really did replay batch 0
        fresh = VectorLoader(SyntheticVectorSource(D, seed=3),
                             batch=BATCH)
        assert pristine == fresh.fingerprint()

    def test_second_failure_hits_plan_cache(self, tmp_path):
        prog, params = _compile()
        loader = VectorLoader(SyntheticVectorSource(D, seed=5),
                              batch=BATCH)
        ckpt = CheckpointManager(tmp_path, keep=10, async_save=False)
        sup = ElasticSupervisor(
            prog, ckpt, loader, runner_factory=_interp_factory,
            checkpoint_every=2,
            injector=RankFailureInjector({3: 3, 6: 1}))
        sup.run(params, 8, log_every=0)
        assert len(sup.reports) == 2
        # 4 -> 2 (shrink dp), then 2 -> 1 (shrink pp: only axis left)
        assert sup.reports[0].new_world == 2
        assert sup.reports[1].new_world == 1
        assert not sup.reports[0].cache_hit
        # different target worlds -> no cache hit; now prewarm and
        # verify a repeat failure at a seen world IS a hit
        sup2_prog, sup2_params = _compile()
        loader2 = VectorLoader(SyntheticVectorSource(D, seed=5),
                               batch=BATCH)
        sup2 = ElasticSupervisor(
            sup2_prog, ckpt, loader2, runner_factory=_interp_factory,
            checkpoint_every=2,
            injector=RankFailureInjector({3: 1}))
        assert sup2.prewarm(1) == 1
        sup2.run(sup2_params, 5, log_every=0)
        assert sup2.reports[0].cache_hit
        assert sup2.reports[0].compile_seconds == 0.0

    def test_failure_budget_exhausts(self, tmp_path):
        prog, params = _compile()
        loader = VectorLoader(SyntheticVectorSource(D, seed=5),
                              batch=BATCH)
        ckpt = CheckpointManager(tmp_path, keep=4, async_save=False)

        class AlwaysFail(RankFailureInjector):
            def check(self, step):
                raise RankFailure(step, 0)

        sup = ElasticSupervisor(
            prog, ckpt, loader, runner_factory=_interp_factory,
            checkpoint_every=2, injector=AlwaysFail(), max_failures=2)
        with pytest.raises(ElasticError, match="budget exhausted"):
            sup.run(params, 8, log_every=0)


# ---------------------------------------------------------------------------
# kill-a-rank on real (faked-host) XLA devices — the SPMD harness
# ---------------------------------------------------------------------------

pytestmark_spmd = [pytest.mark.slow, pytest.mark.elastic]

CHILD = r"""
import json, os, pathlib, sys, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np

from helpers import inputs_spec, make_mlp_forward, make_mlp_params
from repro.checkpoint import CheckpointManager, reshard_tree
from repro.core.compiler import compile_training
from repro.core.strategy import Mesh, Pipeline, Strategy, ZeRO
from repro.data import SyntheticVectorSource, VectorLoader
from repro.ft import (ElasticSupervisor, RankFailureInjector,
                      shrink_for_survivors, sgd_update,
                      zero_shard_degree)
from repro.runtime.spmd import SpmdExecutor

S, D, BATCH = 8, 16, 16
N_STEPS, CKPT_EVERY, FAIL_AT, KILL_RANK = 10, 4, 6, 3

def bits(x):
    return np.asarray(x).tobytes()

def params_bits(tree):
    return [bits(l) for l in jax.tree_util.tree_leaves(tree)]

def spmd_factory(prog, params, devices):
    return SpmdExecutor(prog, params=params, physical_devices=devices)

cases = json.loads(sys.argv[1])
for sched, zero in cases:
    label = f"{sched}/zero{zero}"
    mesh = Mesh(pp=4, dp=2)
    strat = Strategy(mesh, Pipeline(sched, n_mb=4)
                     | ZeRO(stage=zero)).validate()
    params = make_mlp_params(jax.random.PRNGKey(0), S, d=D)
    prog = compile_training(make_mlp_forward(S), params,
                            inputs_spec(BATCH, D), strategy=strat)
    with tempfile.TemporaryDirectory() as td:
        loader = VectorLoader(SyntheticVectorSource(D, seed=11),
                              batch=BATCH)
        ckpt = CheckpointManager(pathlib.Path(td), keep=10,
                                 async_save=False)
        sup = ElasticSupervisor(
            prog, ckpt, loader, runner_factory=spmd_factory,
            checkpoint_every=CKPT_EVERY,
            injector=RankFailureInjector({FAIL_AT: KILL_RANK}))
        final = sup.run(params, N_STEPS, log_every=0)

        assert len(sup.reports) == 1, sup.reports
        r = sup.reports[0]
        assert r.resume_step == 4 and r.step_failed == FAIL_AT
        # resume within one checkpoint interval of lost steps
        assert 0 < r.steps_lost <= CKPT_EVERY, r.steps_lost
        assert r.old_world == 8 and r.new_world == 4
        assert r.shrunk_axis == "dp" and r.failed_rank == KILL_RANK
        # the shrunk program avoided the dead physical device
        assert KILL_RANK not in sup.physical, sup.physical
        assert len(sup.physical) == 4

        # reference: restore the SAME checkpoint onto the SAME shrunk
        # mesh and run uninterrupted
        plan = shrink_for_survivors(
            strat, [x for x in range(8) if x != KILL_RANK])
        ref_prog = prog.recompile(strategy=plan.strategy)
        state, extra = ckpt.restore({"params": params}, step=4)
        assert int(extra["data"]["step"]) == 4, extra["data"]
        rl = VectorLoader(SyntheticVectorSource(D, seed=11),
                          batch=BATCH)
        rl.load_state_dict(extra["data"])
        p = state["params"]
        old_deg, new_deg = (int(extra["zero_shards"]),
                            zero_shard_degree(plan.strategy))
        if old_deg != new_deg:
            p = reshard_tree(p, old_deg, new_deg)
        update = sgd_update()
        ex = SpmdExecutor(ref_prog, params=p)
        ref_losses = {}
        for step in range(4, N_STEPS):
            res = ex.run(rl.next_batch())
            p = update(p, res.grads, step)
            ex.params = p
            ref_losses[step + 1] = float(res.loss)

        got = {h["step"]: h["loss"] for h in sup.history}  # last wins
        for step, ref in ref_losses.items():
            assert bits(np.float64(got[step])) == \
                bits(np.float64(ref)), \
                (label, step, got[step], ref)
        assert params_bits(final) == params_bits(p), label
    print(f"CASE_OK {label}", flush=True)
print("ALL_OK", flush=True)
"""


@pytest.mark.slow
@pytest.mark.elastic
class TestKillARankSpmd:
    """One subprocess runs the whole grid (device-count flag must be set
    before jax initializes; subprocess isolation keeps it from leaking
    into other tests)."""

    def _run_child(self, cases):
        from helpers import run_child_once_retry
        return run_child_once_retry(CHILD, json.dumps(cases),
                                    timeout=600)

    def test_kill_a_rank_grid(self):
        cases = [[sched, zero] for sched in ("1f1b", "gpipe")
                 for zero in (0, 3)]
        out = self._run_child(cases)
        for sched, zero in cases:
            assert f"CASE_OK {sched}/zero{zero}" in out, out
        assert "ALL_OK" in out
