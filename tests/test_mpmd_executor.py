"""MPMD multi-controller executor (runtime/mpmd.py): fp64 bit-parity
against the reference interpreter on 8 faked host XLA devices across
the acceptance grid {1f1b, gpipe, dualpipev} x ZeRO{0, 3}, one case on
the tcp (localhost socket) transport, and the trace-size claim: every
per-rank jit program is strictly smaller than the SPMD whole-mesh
trace for world >= 4.

Parity cases run in subprocesses — the 8-device XLA flag must not leak
into other tests' device counts (the exact failure mode
``launch.hostdevices`` exists to prevent).  The handshake contract
tests run in-process: the PIPER025 signature exchange needs a
transport, not devices (rank programs may oversubscribe one CPU
device), so no subprocess or XLA flag is required."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.mpmd

_ROOT = pathlib.Path(__file__).resolve().parent.parent

CHILD = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)   # fp64 bit-parity
    import numpy as np
    from helpers import (make_mlp_params, make_mlp_forward,
                         inputs_spec, make_batch)
    from repro.core import (compile_training, Mesh, Pipeline, ZeRO,
                            Strategy)
    from repro.runtime import Interpreter
    from repro.runtime.mpmd import MpmdExecutor
    from repro.runtime.spmd import SpmdExecutor

    S, BATCH = 8, 16

    CASES = {
        "1f1b-z0":      lambda: Pipeline("1f1b", n_mb=4) | ZeRO(stage=0),
        "1f1b-z3":      lambda: Pipeline("1f1b", n_mb=4) | ZeRO(stage=3),
        "gpipe-z0":     lambda: Pipeline("gpipe", n_mb=4) | ZeRO(stage=0),
        "gpipe-z3":     lambda: Pipeline("gpipe", n_mb=4) | ZeRO(stage=3),
        "dualpipev-z0": lambda: Pipeline("dualpipev", n_mb=8)
                                | ZeRO(stage=0),
        "dualpipev-z3": lambda: Pipeline("dualpipev", n_mb=8)
                                | ZeRO(stage=3),
        "1f1b-z3-tcp":  lambda: Pipeline("1f1b", n_mb=4) | ZeRO(stage=3),
    }

    def bits(x):
        return np.asarray(x).tobytes()

    def build(name):
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        return compile_training(
            make_mlp_forward(S), params, inputs_spec(BATCH),
            strategy=Strategy(Mesh(pp=4, dp=2), CASES[name]()))

    for name in json.loads(sys.argv[1]):
        if name == "trace-size":
            # acceptance metric: MPMD traces ONLY each rank's chunks, so
            # for world >= 4 every rank program must be strictly smaller
            # than the SPMD whole-mesh trace of the same plan
            prog = build("1f1b-z3")
            batch = make_batch(BATCH)
            per_rank = MpmdExecutor(prog, handshake=False) \\
                .trace_sizes(batch)
            whole = SpmdExecutor(prog).trace_size(batch)
            assert len(per_rank) == 8 and all(
                n < whole for n in per_rank.values()), (per_rank, whole)
            print("TRACE_OK", max(per_rank.values()), "<", whole)
            continue
        transport = "tcp" if name.endswith("-tcp") else "inproc"
        prog = build(name)
        batch = make_batch(BATCH)
        ref = Interpreter(prog).run(batch)
        ex = MpmdExecutor(prog, transport=transport)
        got = ex.run(batch)
        ex.close()
        assert bits(np.float64(ref.loss)) == bits(np.float64(got.loss)), \\
            (name, ref.loss, got.loss)
        assert sorted(ref.grads) == sorted(got.grads), name
        for bkt in ref.grads:
            jax.tree_util.tree_map(
                lambda a, b: (_ for _ in ()).throw(AssertionError(
                    f"{name}:{bkt} grad bits differ")) if bits(a) != bits(b)
                else None,
                ref.grads[bkt], got.grads[bkt])
        assert got.stats["backend"] == "mpmd", got.stats
        print("CASE_OK", name, ref.loss)

    print("MPMD_PARITY_OK")
""")


def _run_child(cases):
    # inherit the parent env (setup-python runners need their exported
    # vars); the child overrides XLA_FLAGS itself before importing jax
    env = {**os.environ, "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": f"{_ROOT / 'src'}{os.pathsep}{_ROOT / 'tests'}"}
    r = subprocess.run(
        [sys.executable, "-c", CHILD, json.dumps(cases)],
        capture_output=True, text=True, timeout=900, env=env)
    assert "MPMD_PARITY_OK" in r.stdout, \
        (r.stdout[-2000:], r.stderr[-4000:])
    for c in cases:
        marker = "TRACE_OK" if c == "trace-size" else f"CASE_OK {c}"
        assert marker in r.stdout, (c, r.stdout[-2000:])


@pytest.mark.slow
def test_parity_1f1b_and_gpipe():
    """Acceptance grid, part 1: {1f1b, gpipe} x ZeRO{0, 3} bit-parity
    on 8 controller threads / 8 faked devices."""
    _run_child(["1f1b-z0", "1f1b-z3", "gpipe-z0", "gpipe-z3"])


@pytest.mark.slow
def test_parity_dualpipev():
    """Acceptance grid, part 2: the split-backward schedule — the
    hardest interleaving for blocking per-rank transports."""
    _run_child(["dualpipev-z0", "dualpipev-z3"])


@pytest.mark.slow
def test_tcp_transport_and_trace_size():
    """Real socket transport parity, plus the per-rank-trace < SPMD
    whole-mesh-trace acceptance bound for world >= 4."""
    _run_child(["1f1b-z3-tcp", "trace-size"])


# ---------------------------------------------------------------------------
# in-process contracts (no faked devices needed — rank programs may
# oversubscribe the single CPU device, and the handshake needs none)
# ---------------------------------------------------------------------------

def _small_prog():
    import jax

    from helpers import inputs_spec, make_mlp_forward, make_mlp_params
    from repro.core import Mesh, Pipeline, Strategy, ZeRO, compile_training

    S, BATCH = 4, 8
    params = make_mlp_params(jax.random.PRNGKey(0), S)
    return compile_training(
        make_mlp_forward(S), params, inputs_spec(BATCH),
        strategy=Strategy(Mesh(pp=2, dp=2),
                          Pipeline("1f1b", n_mb=2) | ZeRO(stage=3)))


def test_handshake_corrupt_signature_names_both_ranks():
    """PIPER025 negative path: a rank whose wire signature disagrees
    with its peers must fail the startup handshake with an error naming
    the code and BOTH ends of the broken channel."""
    from repro.runtime.mpmd import MpmdExecutor, MpmdHandshakeError

    prog = _small_prog()
    sig = prog.plan.rank_signature(1, prog.dag)
    # drop one p2p endpoint: the peer now advertises a channel length
    # rank 1 does not — exactly what a mis-deployed rank binary does
    if sig["sends"]:
        peer = sig["sends"][0][0]
        sig = {**sig, "sends": sig["sends"][1:]}
    else:
        peer = sig["recvs"][0][0]
        sig = {**sig, "recvs": sig["recvs"][1:]}
    with pytest.raises(MpmdHandshakeError) as ei:
        MpmdExecutor(prog, signature_overrides={1: sig})
    msg = str(ei.value)
    assert "PIPER025" in msg, msg
    assert "rank 1" in msg, msg
    assert f"rank {peer}" in msg, msg


def test_handshake_garbage_bytes_rejected():
    """A byte-level corrupt signature (truncated JSON from a flaky
    bootstrap) must surface as a handshake failure, not a hang or a
    silent desync later."""
    from repro.runtime.mpmd import (MpmdBackendError, MpmdExecutor,
                                    MpmdHandshakeError)

    prog = _small_prog()
    with pytest.raises((MpmdHandshakeError, MpmdBackendError)) as ei:
        MpmdExecutor(prog, timeout=10.0,
                     signature_overrides={
                         2: b'{"device": 2, "sends": [], "recvs": [],'
                            b' "collectives": []}'})
    msg = str(ei.value)
    assert "PIPER025" in msg, msg
    assert "rank 2" in msg, msg


def test_matching_signatures_handshake_ok():
    """Positive control: the untampered pairwise exchange succeeds and
    the executor is usable (constructor returns, transport reset)."""
    from repro.runtime.mpmd import MpmdExecutor

    prog = _small_prog()
    ex = MpmdExecutor(prog)          # handshake on by default
    assert ex.n == 4
    ex.close()


def test_unknown_transport_rejected():
    from repro.runtime.mpmd import MpmdBackendError, MpmdExecutor

    prog = _small_prog()
    with pytest.raises(MpmdBackendError, match="carrier-pigeon"):
        MpmdExecutor(prog, transport="carrier-pigeon")


def test_invalid_comm_order_rejected_before_threads():
    """Same static gate as the SPMD executor: a plan failing
    ``validate_comm_order`` is rejected in the constructor, before any
    controller thread or handshake exists."""
    from repro.core import (CompiledProgram, ScheduleRejected, TrainingDAG,
                            ValueSpec)
    from repro.core.plan import ROLE_COLL, DevicePlan, GlobalPlan, Task
    from repro.runtime.mpmd import MpmdExecutor

    dag = TrainingDAG()
    ag = dag.new_node(kind="comm", op="all_gather", name="ag",
                      devices=(0, 1), group=(0, 1), payload="param",
                      out_specs=[ValueSpec((8,))])
    ar = dag.new_node(kind="comm", op="all_reduce", name="ar",
                      devices=(0, 1), group=(0, 1), payload="grad",
                      out_specs=[ValueSpec((8,))])
    p0, p1 = DevicePlan(device=0), DevicePlan(device=1)
    p0.append(Task(ag.id, 0, ROLE_COLL, "zero"))
    p0.append(Task(ar.id, 0, ROLE_COLL, "zero"))
    p1.append(Task(ar.id, 1, ROLE_COLL, "zero"))  # flipped on rank 1
    p1.append(Task(ag.id, 1, ROLE_COLL, "zero"))
    plan = GlobalPlan(device_plans={0: p0, 1: p1}, priorities={},
                      devices=[0, 1])
    prog = CompiledProgram(dag=dag, plan=plan, params={}, schedule=())
    with pytest.raises(ScheduleRejected, match="dispatch order"):
        MpmdExecutor(prog)


def test_rank_orders_cover_all_tasks():
    """The deadlock-free witness orders (``_rank_orders``) must be a
    permutation of each rank's tasks, and pin every compute/collective
    to the interpreter's replayed dispatch order (bit-parity)."""
    from helpers import make_batch
    from repro.core.plan import ROLE_RECV, ROLE_SEND
    from repro.runtime.mpmd import MpmdExecutor

    prog = _small_prog()
    ex = MpmdExecutor(prog, handshake=False)
    replay = ex._resolver.replay(make_batch(8))
    orders = ex._rank_orders(replay)
    for r in ex.devices:
        want = sorted((t.node, t.role)
                      for t in prog.plan.plan_for(r).tasks.values())
        assert sorted(orders[r]) == want, r
        pinned = [(n, role) for (n, role) in orders[r]
                  if role not in (ROLE_SEND, ROLE_RECV)]
        want_pin = [(n, role) for (n, d, role) in replay.exec_order
                    if d == r and role not in (ROLE_SEND, ROLE_RECV)]
        assert pinned == want_pin, r
