"""Strategy autotuner: search determinism under a fixed cost model,
plan-cache round-trip, memory-budget rejection, and the tentpole claim —
the searched strategy beats the default 1F1B baseline on the simulator
for multiple configs."""
import jax
import pytest

from repro import tune
from repro.configs import get_config
from repro.runtime.costmodel import CostModel

jax.config.update("jax_platform_name", "cpu")

TOKENS = 8192
SPACE = tune.SearchSpace(mb_multipliers=(2, 4))


def small_search(name="qwen3-1b", mesh=None, budget=None, **kw):
    mesh = mesh or tune.MeshSpec(pp=2, dp=1)
    kw.setdefault("tokens", TOKENS)
    kw.setdefault("space", SPACE)
    kw.setdefault("use_cache", False)
    return tune.search(get_config(name), mesh, budget, **kw)


class TestSearch:
    def test_deterministic_given_fixed_cost_model(self):
        cost = CostModel()
        a = small_search(cost=cost)
        b = small_search(cost=cost)
        assert a.candidate == b.candidate
        assert a.predicted_step_seconds == b.predicted_step_seconds
        assert a.predicted_peak_bytes == b.predicted_peak_bytes
        assert [s.candidate for s in a.leaderboard] == \
            [s.candidate for s in b.leaderboard]

    def test_winner_beats_1f1b_baseline_on_two_configs(self):
        """Acceptance: for >=2 tested configs the searched strategy's
        simulator-predicted step time beats the default 1F1B plan."""
        wins = 0
        for name in ("qwen3-1b", "qwen3-9b"):
            plan = small_search(name)
            assert plan.baseline.candidate.kind == "1f1b"
            if plan.predicted_step_seconds < plan.baseline.step_seconds:
                wins += 1
        assert wins >= 2

    def test_directives_compile(self):
        """The winning plan's directive list round-trips through the
        real compiler (the proxy program IS a Piper program)."""
        plan = small_search()
        d = plan.directives()
        cfg = get_config("qwen3-1b")
        prog, _ = tune.build_candidate_program(
            cfg, plan.mesh, plan.candidate, TOKENS)
        assert prog.plan.devices == list(range(plan.mesh.n_devices))
        names = {type(x).__name__ for x in d}
        assert {"Place", "Split", "Order"} <= names

    def test_moe_config_opens_ep_axis(self):
        mesh = tune.MeshSpec(pp=2, dp=2)
        cfg = get_config("deepseek-moe-16b")
        cands = list(SPACE.candidates(cfg, mesh, TOKENS))
        assert any(c.ep == 2 for c in cands)
        dense = list(SPACE.candidates(get_config("qwen3-1b"), mesh,
                                      TOKENS))
        assert all(c.ep == 1 for c in dense)


class TestPlanCache:
    def test_round_trip_identical_directives(self, tmp_path):
        kw = dict(tokens=TOKENS, space=SPACE, cache_dir=str(tmp_path))
        first = tune.search(get_config("qwen3-1b"),
                            tune.MeshSpec(pp=2, dp=1), None, **kw)
        assert not first.from_cache
        second = tune.search(get_config("qwen3-1b"),
                             tune.MeshSpec(pp=2, dp=1), None, **kw)
        assert second.from_cache
        assert second.candidate == first.candidate
        assert second.predicted_step_seconds == \
            first.predicted_step_seconds
        assert repr(second.directives()) == repr(first.directives())

    def test_key_sensitivity(self, tmp_path):
        """Different budget / mesh / tokens never share a cache entry."""
        kw = dict(tokens=TOKENS, space=SPACE, cache_dir=str(tmp_path))
        tune.search(get_config("qwen3-1b"), tune.MeshSpec(pp=2), None,
                    **kw)
        other = tune.search(get_config("qwen3-1b"), tune.MeshSpec(pp=2),
                            10**15, **kw)
        assert not other.from_cache

    def test_plan_serialization_round_trip(self):
        plan = small_search()
        d = plan.to_dict()
        back = tune.Plan.from_dict(d, config=get_config("qwen3-1b"))
        assert back.candidate == plan.candidate
        assert back.baseline.step_seconds == plan.baseline.step_seconds
        assert repr(back.directives()) == repr(plan.directives())

    def test_plan_dict_stores_strategies_not_candidate_tuples(self):
        """The serialized plan (the cache payload) speaks the Strategy
        dialect: winner/baseline/leaderboard are schema-versioned
        strategy documents, not Candidate field tuples."""
        from repro.core.strategy import SCHEMA_VERSION, Strategy
        plan = small_search(mesh=tune.MeshSpec(pp=2, dp=2))
        d = plan.to_dict()
        assert "candidate" not in d
        assert d["strategy"]["schema"] == SCHEMA_VERSION
        assert d["mesh"] == {"axes": [["pp", 2], ["dp", 2]]}
        for entry in [d["baseline"], *d["leaderboard"]]:
            assert "candidate" not in entry
            strat = Strategy.from_dict(entry["strategy"])
            assert strat.pipeline is not None
        # winner document == plan.strategy() canonical JSON
        assert Strategy.from_dict(d["strategy"]) == plan.strategy()

    def test_stale_strategy_schema_entry_ignored(self, tmp_path, caplog):
        """A cache entry written under another strategy schema is
        skipped with a logged warning and the search re-runs."""
        import json
        import logging
        kw = dict(tokens=TOKENS, space=SPACE, cache_dir=str(tmp_path))
        tune.search(get_config("qwen3-1b"), tune.MeshSpec(pp=2, dp=1),
                    None, **kw)
        entries = list(tmp_path.glob("*.json"))
        assert entries
        for p in entries:
            doc = json.loads(p.read_text())
            doc["strategy_schema"] = 0
            p.write_text(json.dumps(doc))
        with caplog.at_level(logging.WARNING, logger="repro.tune.cache"):
            again = tune.search(get_config("qwen3-1b"),
                                tune.MeshSpec(pp=2, dp=1), None, **kw)
        assert not again.from_cache
        assert any("strategy schema" in r.getMessage()
                   for r in caplog.records)

    def test_old_keys_invalidate_on_schema_bump(self, monkeypatch):
        """Fingerprints derive from the strategy schema: bumping it
        yields different cache keys for identical inputs."""
        from repro.tune import cache as tc
        k1 = tc.fingerprint(config="c", mesh={"axes": [["pp", 2]]})
        monkeypatch.setattr(tc, "STRATEGY_SCHEMA_VERSION", -1)
        k2 = tc.fingerprint(config="c", mesh={"axes": [["pp", 2]]})
        assert k1 != k2


class TestMemoryBudget:
    def test_budget_rejects_heavy_candidates(self):
        free = small_search()
        peaks = sorted(s.peak_bytes for s in free.leaderboard)
        assert peaks[0] < peaks[-1]
        budget = (peaks[0] + peaks[-1]) // 2
        capped = small_search(budget=budget)
        assert capped.n_rejected > 0
        assert capped.predicted_peak_bytes <= budget

    def test_impossible_budget_raises(self):
        with pytest.raises(tune.NoFeasiblePlanError):
            small_search(budget=1)

    def test_zero3_shards_persistent_state(self):
        """ZeRO-3 shards weights across the DP group (persistent bytes
        drop per bucket), and the timeline estimate charges the
        full-param gather buffers on top (so ZeRO-3 peak is NOT simply
        persistent/dp — the elide_allgathers pass keeps a gathered
        buffer alive across each microbatch's F->B span)."""
        from repro.runtime.memory import bucket_persistent_bytes
        cfg = get_config("qwen3-9b")
        mesh = tune.MeshSpec(pp=2, dp=2)
        persist = {}
        peak = {}
        for zero in (1, 3):
            cand = tune.Candidate(kind="1f1b", n_mb=4, zero=zero)
            prog, _ = tune.build_candidate_program(cfg, mesh, cand,
                                                   TOKENS)
            persist[zero] = sum(bucket_persistent_bytes(b, 0)
                                for b in prog.dag.buckets.values())
            peak[zero] = tune.score_candidate(
                cfg, mesh, cand, tokens=TOKENS).peak_bytes
        assert persist[3] < persist[1]
        # gather buffers are charged: peak exceeds the sharded persistent
        assert peak[3] > persist[3] // 2  # (2 of 4 stages per device)

    def test_gpipe_stashes_more_than_1f1b(self):
        """Activation high-water: gpipe keeps every microbatch's
        boundary activations alive; 1f1b caps in-flight microbatches."""
        cfg = get_config("qwen1.5-0.5b")
        mesh = tune.MeshSpec(pp=2, dp=1)
        n_mb = 16
        big_tokens = 65536
        pk = {}
        for kind in ("gpipe", "1f1b"):
            s = tune.score_candidate(
                cfg, mesh, tune.Candidate(kind=kind, n_mb=n_mb),
                tokens=big_tokens)
            pk[kind] = s.peak_bytes
        assert pk["gpipe"] > pk["1f1b"]
