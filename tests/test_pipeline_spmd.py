"""SPMD pipeline executor: numerics vs sequential execution on 4
simulated host devices (subprocess — the 512-device flag must not leak
into other tests)."""
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # 4-device subprocess; scripts/tier1.sh skips

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import _mk
    from repro.parallel.pipeline import pipeline_apply, pipeline_loss

    R, M, MB, D = 4, 8, 4, 16
    mesh = _mk((R,), ("pipe",))
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, R)
    params = {
        "w1": jax.vmap(lambda k: jax.random.normal(k, (D, D)) * 0.3)(ks),
        "w2": jax.vmap(lambda k: jax.random.normal(k, (D, D)) * 0.3)(
            jax.vmap(jax.random.fold_in)(ks, jnp.arange(R))),
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))
    y = jax.random.normal(jax.random.PRNGKey(2), (M, MB, D))

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w1"]) @ p["w2"]

    def loss_fn(out, y):
        return jnp.mean((out - y) ** 2)

    # --- pipeline ---
    def pl_loss(params):
        return pipeline_loss(stage_fn, loss_fn, params, x, y, mesh=mesh)
    l_pp, g_pp = jax.value_and_grad(pl_loss)(params)

    # --- sequential oracle ---
    def seq_loss(params):
        out = x
        for r in range(R):
            pr = jax.tree_util.tree_map(lambda a: a[r], params)
            out = jax.vmap(lambda xm: stage_fn(pr, xm))(out)
        return loss_fn(out, y)
    l_seq, g_seq = jax.value_and_grad(seq_loss)(params)

    np.testing.assert_allclose(float(l_pp), float(l_seq), rtol=1e-5)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(a, b, atol=1e-5,
                                                rtol=1e-4), g_pp, g_seq)
    print("PIPELINE_OK", float(l_pp))
""")


def test_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert "PIPELINE_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-3000:])
