"""shard_map all-to-all MoE (moe_block_ep) numerics vs the dense-dispatch
oracle on 8 simulated devices (subprocess, mesh (2,4))."""
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # 8-device subprocess; scripts/tier1.sh skips

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import _mk
    from repro.models import layers as L

    mesh = _mk((2, 4), ("data", "model"))
    E, K, D, DEX = 8, 2, 16, 32
    B, S = 4, 16
    p = L.init_moe(jax.random.PRNGKey(0), D, DEX, E, 0, "swiglu",
                   jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)) * 0.5

    kw = dict(n_experts=E, top_k=K, act="swiglu", capacity_factor=8.0)
    set_mesh = getattr(jax, "set_mesh", None)
    with (set_mesh(mesh) if set_mesh is not None else mesh):
        def f_ep(p, x):
            y, aux = L.moe_block_ep(p, x, mesh=mesh, dp_axes=("data",),
                                    tp_axis="model", **kw)
            return jnp.sum(y ** 2), (y, aux)
        (loss_ep, (y_ep, aux_ep)), g_ep = jax.value_and_grad(
            f_ep, has_aux=True)(p, x)

    def f_dense(p, x):
        y, aux = L.moe_block_dense(p, x, **kw)
        return jnp.sum(y ** 2), (y, aux)
    (loss_d, (y_d, aux_d)), g_d = jax.value_and_grad(
        f_dense, has_aux=True)(p, x)

    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_d),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(float(aux_ep), float(aux_d), atol=1e-5)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-3),
        g_ep, g_d)
    print("MOE_EP_OK", float(loss_ep), float(loss_d))
""")


def test_moe_ep_matches_dense():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu", "HOME": "/root"})
    assert "MOE_EP_OK" in r.stdout, (r.stdout[-1500:], r.stderr[-3000:])
