"""Programmable activation-memory directives (DESIGN.md §11):

- ``Remat(policy=...)``: "full" reproduces the historical per-chunk
  rematerialization bit-identically; "none" stashes the vjp residuals —
  measurably less backward compute (XLA cost analysis) and more live
  activation memory in BOTH the interpreter ledger and the static
  ``timeline_peak_bytes`` estimate; "selective" lands in between.
- ``Offload(depth=...)``: host round-trips free the device between
  stash and fetch, with bit-identical numerics.
- ledger coverage: interpreter-measured peaks match the static estimate
  within the documented slack for remat on/off across
  {1f1b, gpipe, dualpipev} x ZeRO {0, 3}.
- ``gather_param_bytes`` fails loudly on unknown buckets (regression).
- ``Pipeline(cap_offset=...)`` sweeps the dualpipev in-flight cap.
- the autotuner's ``Candidate.remat`` axis + memory budget pick the
  cheapest schedule that fits.
"""
import jax
import numpy as np
import pytest

from helpers import (assert_grads_close, inputs_spec, make_batch,
                     make_mlp_forward, make_mlp_params, mlp_oracle)
from repro.core import (Mesh, Offload, Pipeline, Remat, Strategy,
                        StrategyError, ZeRO, compile_training)
from repro.runtime import Interpreter
from repro.runtime.costmodel import CostModel, analyze_fn
from repro.runtime.memory import timeline_peak_bytes
from repro.runtime.simulator import TimelineSimulator

jax.config.update("jax_platform_name", "cpu")

S = 4
BATCH = 16
N_MB = 4

# Documented slack between the interpreter's exact per-device ledger and
# the static timeline estimate (docs/memory.md): the estimator excludes
# graph-input buffers, approximates DP-sharded activations as
# 1/len(devices) of the unsharded spec, and models ZeRO-3 buffer
# lifetimes from the simulated timeline rather than the interpreter's
# dynamic rate limiter.  Empirically <= ~23% on these programs.
LEDGER_SLACK = 0.30


def build(kind="1f1b", policy=None, zero=None, offload=None,
          batch=BATCH):
    params = make_mlp_params(jax.random.PRNGKey(0), S)
    frags = Pipeline(kind, n_mb=N_MB)
    mesh = Mesh(pp=2, dp=2) if zero is not None else Mesh(pp=2)
    if zero is not None:
        frags = frags | ZeRO(stage=zero)
    if policy is not None:
        frags = frags | Remat(policy)
    if offload is not None:
        frags = frags | offload
    prog = compile_training(make_mlp_forward(S), params,
                            inputs_spec(batch),
                            strategy=Strategy(mesh, frags))
    return prog, params


def run_and_check(prog, params, batch):
    res = Interpreter(prog).run(batch)
    l, g = mlp_oracle(params, batch["x"], batch["y"], S)
    assert res.loss == pytest.approx(l, abs=1e-6)
    assert_grads_close(res.grads, g)
    return res


def static_peaks(prog):
    sim = TimelineSimulator(prog, CostModel(ici_bw=1e12, comm_latency=0.0),
                            chunk_seconds_override=lambda n: 1e-3).run()
    return timeline_peak_bytes(prog, sim.records)


class TestNumerics:
    def test_full_bit_identical_to_default(self):
        """Acceptance: Remat(policy="full") reproduces today's numerics
        bit-identically (it IS today's autodiff path, undisturbed)."""
        batch = make_batch(BATCH)
        base, params = build()
        expl, _ = build(policy="full")
        a = Interpreter(base).run(batch)
        b = Interpreter(expl).run(batch)
        assert a.loss == b.loss
        for bucket in a.grads:
            for u, v in zip(jax.tree_util.tree_leaves(a.grads[bucket]),
                            jax.tree_util.tree_leaves(b.grads[bucket])):
                assert np.array_equal(np.asarray(u), np.asarray(v))
        assert a.peak_bytes() == b.peak_bytes()

    @pytest.mark.parametrize("kind,policy,zero", [
        ("1f1b", "none", None), ("1f1b", "selective", None),
        ("dualpipev", "none", 3)])
    def test_policies_match_oracle(self, kind, policy, zero):
        """Stashed residuals (incl. the ZeroBubble Bi/Bw split under
        dualpipev x ZeRO-3) still reproduce the unscheduled model."""
        prog, params = build(kind=kind, policy=policy, zero=zero)
        run_and_check(prog, params, make_batch(BATCH))

    def test_scope_restricts_policy(self):
        """Remat(scope={"pp": 0}) stashes only stage 0; other stages
        keep the full-remat backward."""
        prog, params = build()
        scoped, _ = build(policy=None)
        params2 = make_mlp_params(jax.random.PRNGKey(0), S)
        frags = (Pipeline("1f1b", n_mb=N_MB)
                 | Remat("none", scope={"pp": 0}))
        prog2 = compile_training(make_mlp_forward(S), params2,
                                 inputs_spec(BATCH),
                                 strategy=Strategy(Mesh(pp=2), frags))
        remats = {n.dims.get("pp"): n.meta.get("remat")
                  for n in prog2.dag.chunks()
                  if n.dims.get("PASS") == "F"}
        assert remats[0] == "none"
        assert all(v is None for s, v in remats.items() if s != 0)
        run_and_check(prog2, params2, make_batch(BATCH))


class TestComputeMemoryTradeoff:
    def test_none_lowers_backward_compute(self):
        """Acceptance: policy="none" lowers measured recompute time —
        XLA's own cost analysis of the backward exec functions reports
        fewer FLOPs (~2xF vs the remat path's ~3xF)."""
        params = make_mlp_params(jax.random.PRNGKey(0), S, d=64)
        fwd = make_mlp_forward(S)
        flops = {}
        for policy in ("full", "none"):
            frags = Pipeline("1f1b", n_mb=N_MB) | Remat(policy)
            prog = compile_training(fwd, params, inputs_spec(64, d=64),
                                    strategy=Strategy(Mesh(pp=2), frags))
            sim = TimelineSimulator(prog, CostModel())
            total = 0.0
            for n in prog.dag.chunks():
                if n.dims.get("PASS") != "B":
                    continue
                f, _ = analyze_fn(n.fn, params.get(n.bucket),
                                  sim._sample_inputs(n))
                total += f
            flops[policy] = total
        assert flops["none"] < 0.8 * flops["full"], flops

    def test_none_raises_peak_in_both_ledgers(self):
        """Acceptance: policy="none" raises measured peak activation
        bytes in the interpreter ledger AND timeline_peak_bytes;
        "selective" lands strictly between."""
        batch = make_batch(BATCH)
        interp, static = {}, {}
        for policy in ("full", "selective", "none"):
            prog, params = build(policy=policy)
            interp[policy] = run_and_check(prog, params,
                                           batch).max_peak()
            static[policy] = max(static_peaks(prog).values())
        for peaks in (interp, static):
            assert peaks["full"] < peaks["selective"] < peaks["none"], \
                peaks


class TestOffload:
    def test_offload_bit_identical_and_frees_device(self):
        """Host round-trips change nothing numerically and lower the
        device peak in both ledgers."""
        batch = make_batch(BATCH)
        runs = {}
        for off in (None, Offload(depth=1)):
            prog, params = build(policy="none", offload=off)
            runs[off is not None] = (Interpreter(prog).run(batch), prog)
        a, b = runs[False][0], runs[True][0]
        assert a.loss == b.loss
        for bucket in a.grads:
            for u, v in zip(jax.tree_util.tree_leaves(a.grads[bucket]),
                            jax.tree_util.tree_leaves(b.grads[bucket])):
                assert np.array_equal(np.asarray(u), np.asarray(v))
        prog_off = runs[True][1]
        assert prog_off.dag.meta["offload"]["pairs"] > 0
        assert b.max_peak() < a.max_peak()
        assert max(static_peaks(prog_off).values()) < \
            max(static_peaks(runs[False][1]).values())
        # round-trips ride dedicated per-direction DMA lanes
        streams = {n.stream for n in prog_off.dag.comms()
                   if n.op in ("d2h", "h2d")}
        assert streams == {"offload#out", "offload#in"}

    def test_depth_bounds_offloaded_windows(self):
        """Only stash windows deeper than ``depth`` round-trip, so a
        larger depth offloads fewer residuals."""
        pairs = {}
        for depth in (1, 8):
            prog, _ = build(policy="none", offload=Offload(depth=depth))
            pairs[depth] = prog.dag.meta["offload"]["pairs"]
        assert pairs[8] < pairs[1]

    def test_offload_payload_validated(self):
        with pytest.raises(StrategyError, match="payload"):
            Strategy(Mesh(pp=2), Pipeline("1f1b", n_mb=2)
                     | Offload(payload="grad")).validate()


class TestLedgerVsStatic:
    @pytest.mark.parametrize("kind", ["1f1b", "gpipe", "dualpipev"])
    @pytest.mark.parametrize("zero", [0, 3])
    @pytest.mark.parametrize("policy", ["full", "none"])
    def test_interpreter_matches_static_estimate(self, kind, zero,
                                                 policy):
        """The interpreter-measured per-device peaks and the static
        timeline estimate agree within the documented slack for every
        (schedule x ZeRO x remat) combination."""
        prog, params = build(kind=kind, policy=policy, zero=zero)
        res = run_and_check(prog, params, make_batch(BATCH))
        interp = res.peak_bytes()
        static = static_peaks(prog)
        assert set(interp) == set(static)
        for d in interp:
            rel = abs(static[d] - interp[d]) / max(interp[d], 1)
            assert rel <= LEDGER_SLACK, (
                f"dev{d}: interpreter {interp[d]} vs static {static[d]} "
                f"({rel:.1%} > {LEDGER_SLACK:.0%} slack)")


class TestGatherParamBytes:
    def test_missing_bucket_raises(self):
        """Regression: a fused gather naming a bucket absent from
        dag.buckets must raise instead of silently undercounting."""
        from repro.core import TrainingDAG, ValueSpec
        from repro.runtime.memory import gather_param_bytes
        dag = TrainingDAG()
        dag.bucket_of("stage0").param_elems = 10
        g = dag.new_node(kind="comm", op="all_gather", name="ag",
                         devices=(0, 1), group=(0, 1), payload="param",
                         out_specs=[ValueSpec((8,))],
                         meta={"buckets": ["stage0", "ghost"]})
        with pytest.raises(KeyError) as ei:
            gather_param_bytes(dag, g)
        assert "ghost" in str(ei.value)      # names the missing bucket
        assert "ag" in str(ei.value)         # ... and the gather node

    def test_known_buckets_sum(self):
        from repro.core import TrainingDAG, ValueSpec
        from repro.runtime.memory import (WEIGHT_BYTES_PER_ELEM,
                                          gather_param_bytes)
        dag = TrainingDAG()
        dag.bucket_of("a").param_elems = 10
        dag.bucket_of("b").param_elems = 5
        g = dag.new_node(kind="comm", op="all_gather", name="ag",
                         devices=(0,), group=(0,), payload="param",
                         out_specs=[ValueSpec((8,))],
                         meta={"buckets": ["a", "b"]})
        assert gather_param_bytes(dag, g) == 15 * WEIGHT_BYTES_PER_ELEM


class TestCapOffset:
    @staticmethod
    def _max_inflight(seq):
        """Peak (F started - Bi retired), counting an overlapped (F, Bi)
        pair as one atomic step like the generator's cap check does."""
        live, peak = 0, 0
        for ops in seq:
            for op in (ops if isinstance(ops, tuple) else (ops,)):
                if op.pas == "F":
                    live += 1
                elif op.pas in ("B", "Bi"):
                    live -= 1
            peak = max(peak, live)
        return peak

    def test_cap_offset_bounds_inflight(self):
        from repro.core.schedules import build_rank_sequences
        R, M, S_ = 2, 8, 4
        tight = build_rank_sequences("dualpipev", R, M, S_, cap_offset=0)
        default = build_rank_sequences("dualpipev", R, M, S_)
        assert tight != default
        for r in range(R):
            assert self._max_inflight(tight[r]) <= 2 * (R - r)

    def test_pipeline_fragment_plumbs_cap_offset(self):
        """Pipeline(cap_offset=...) changes the lowered schedule and
        round-trips through JSON."""
        def orders(cap):
            strat = Strategy(Mesh(pp=2),
                             Pipeline("dualpipev", n_mb=8,
                                      cap_offset=cap))
            return [repr(d) for d in strat.lower(expert_stages=())]
        assert orders(0) != orders(None)
        s = Strategy(Mesh(pp=2), Pipeline("dualpipev", n_mb=8,
                                          cap_offset=2))
        back = Strategy.from_json(s.to_json())
        assert back == s and back.pipeline.cap_offset == 2
        with pytest.raises(StrategyError, match="cap_offset"):
            Strategy(Mesh(pp=2), Pipeline("1f1b", n_mb=2,
                                          cap_offset=-1)).validate()


class TestFragmentSerialization:
    def test_remat_offload_round_trip_byte_stable(self):
        s = Strategy(Mesh(pp=2, dp=2),
                     Pipeline("1f1b", n_mb=4) | ZeRO(stage=3)
                     | Remat("selective", scope={"pp": 1})
                     | Offload(depth=3))
        doc = s.to_json()
        back = Strategy.from_json(doc)
        assert back == s
        assert back.to_json() == doc
        assert back.remat.scope_dict() == {"pp": 1}

    def test_remat_policy_validated(self):
        with pytest.raises(StrategyError, match="policy"):
            Strategy(Mesh(pp=2), Pipeline("1f1b", n_mb=2)
                     | Remat("checkpoint")).validate()

    def test_label_mentions_remat_and_offload(self):
        s = Strategy(Mesh(pp=2), Pipeline("1f1b", n_mb=4)
                     | Remat("none") | Offload(depth=2))
        assert "rm-none" in s.label() and "off2" in s.label()


class TestTunerRematAxis:
    @staticmethod
    def _space():
        from repro.tune import SearchSpace
        return SearchSpace(kinds=("1f1b",), mb_multipliers=(2,),
                           remat_policies=("full", "none"))

    def test_candidate_round_trip(self):
        from repro.tune import Candidate, MeshSpec
        c = Candidate("1f1b", n_mb=4, zero=3, remat="none")
        assert Candidate.from_dict(c.to_dict()) == c
        s = c.to_strategy(MeshSpec(pp=2, dp=2))
        assert s.remat.policy == "none"
        assert Candidate.from_strategy(s) == c
        assert "rm-none" in c.label()

    def test_budget_rejects_over_budget_picks_feasible(self):
        """Acceptance: with --memory-budget the autotuner rejects the
        faster-but-bigger remat=none candidate and selects the feasible
        full-remat one; unconstrained, remat=none wins on step time."""
        from repro import tune
        from repro.configs import get_config
        cfg = get_config("qwen3-1b")
        mesh = tune.MeshSpec(pp=2, dp=1)
        tokens = 8192
        scores = {c.remat: tune.score_candidate(cfg, mesh, c,
                                                tokens=tokens)
                  for c in self._space().candidates(cfg, mesh, tokens)}
        assert scores["none"].step_seconds < scores["full"].step_seconds
        assert scores["none"].peak_bytes > scores["full"].peak_bytes
        budget = (scores["full"].peak_bytes
                  + scores["none"].peak_bytes) // 2
        plan = tune.search(cfg, mesh, budget, tokens=tokens,
                           space=self._space(), use_cache=False)
        assert plan.candidate.remat == "full"
        assert plan.n_rejected >= 1
        free = tune.search(cfg, mesh, None, tokens=tokens,
                           space=self._space(), use_cache=False)
        assert free.candidate.remat == "none"
