"""SPMD plan executor (runtime/spmd.py): fp64 bit-parity against the
reference interpreter on 8 faked host XLA devices, across
{1f1b, gpipe, dualpipev, zb1f1b} x ZeRO{0,1,2,3} x remat{full,none}
(+ overlap fusion, expert-parallel a2a, offload round-trips), plus the
hang-detection contract: a plan failing ``validate_comm_order`` is
rejected BEFORE tracing.

Parity cases run in subprocesses — the 8-device XLA flag must not leak
into other tests' device counts (the exact failure mode
``launch.hostdevices`` exists to prevent)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.spmd]

_ROOT = pathlib.Path(__file__).resolve().parent.parent

CHILD = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)   # fp64 bit-parity
    import numpy as np
    from helpers import (make_mlp_params, make_mlp_forward,
                         make_moe_forward, inputs_spec, make_batch)
    from repro.core import (compile_training, Mesh, Pipeline, ZeRO,
                            Strategy, Remat, Offload, Overlap,
                            ExpertParallel)
    from repro.runtime import Interpreter
    from repro.runtime.spmd import SpmdExecutor

    S, BATCH, D = 8, 16, 16

    CASES = {
        "1f1b-z0-full":
            lambda: Pipeline("1f1b", n_mb=4) | ZeRO(stage=0),
        "1f1b-z3-none":
            lambda: Pipeline("1f1b", n_mb=4) | ZeRO(stage=3)
            | Remat(policy="none"),
        "gpipe-z1-full":
            lambda: Pipeline("gpipe", n_mb=4) | ZeRO(stage=1),
        "gpipe-z3-overlap":
            lambda: Pipeline("gpipe", n_mb=4) | ZeRO(stage=3)
            | Overlap(prefetch=2, bucket_mb=32),
        "dualpipev-z1-none":
            lambda: Pipeline("dualpipev", n_mb=8) | ZeRO(stage=1)
            | Remat(policy="none"),
        "dualpipev-z3-full":
            lambda: Pipeline("dualpipev", n_mb=8) | ZeRO(stage=3),
        "zb1f1b-z1-full":
            lambda: Pipeline("zb1f1b", n_mb=4) | ZeRO(stage=1),
        "1f1b-z2-offload":
            lambda: Pipeline("1f1b", n_mb=4) | ZeRO(stage=2)
            | Offload(depth=2),
        "1f1b-z1-ep":
            lambda: Pipeline("1f1b", n_mb=4) | ZeRO(stage=1)
            | ExpertParallel(),
    }

    def bits(x):
        return np.asarray(x).tobytes()

    for name in json.loads(sys.argv[1]):
        moe = name.endswith("-ep")
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        if moe:
            fwd = make_moe_forward(S)
            for i in (1, 3, 5):
                k = jax.random.PRNGKey(100 + i)
                params[f"exp{i}"] = {
                    "w1": jax.random.normal(k, (D, D)) * 0.1,
                    "w2": jax.random.normal(
                        jax.random.fold_in(k, 1), (D, D)) * 0.1}
        else:
            fwd = make_mlp_forward(S)
        prog = compile_training(
            fwd, params, inputs_spec(BATCH),
            strategy=Strategy(Mesh(pp=4, dp=2), CASES[name]()))
        batch = make_batch(BATCH)
        ref = Interpreter(prog).run(batch)
        got = SpmdExecutor(prog).run(batch)
        assert bits(np.float64(ref.loss)) == bits(np.float64(got.loss)), \\
            (name, ref.loss, got.loss)
        assert sorted(ref.grads) == sorted(got.grads), name
        for bkt in ref.grads:
            jax.tree_util.tree_map(
                lambda a, b: (_ for _ in ()).throw(AssertionError(
                    f"{name}:{bkt} grad bits differ")) if bits(a) != bits(b)
                else None,
                ref.grads[bkt], got.grads[bkt])
        print("CASE_OK", name, ref.loss)

    # tune.measure_program: the public measured-column entry point,
    # exercised with its default synth-batch/params fallbacks on the
    # last compiled program of this child
    from repro import tune
    t = tune.measure_program(prog, reps=1)
    assert t > 0, t
    print("MEASURE_OK", t)
    print("SPMD_PARITY_OK")
""")


def _run_child(cases):
    # inherit the parent env (setup-python runners need their exported
    # vars); the child overrides XLA_FLAGS itself before importing jax
    env = {**os.environ, "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": f"{_ROOT / 'src'}{os.pathsep}{_ROOT / 'tests'}"}
    r = subprocess.run(
        [sys.executable, "-c", CHILD, json.dumps(cases)],
        capture_output=True, text=True, timeout=600, env=env)
    assert "SPMD_PARITY_OK" in r.stdout, \
        (r.stdout[-2000:], r.stderr[-4000:])
    for c in cases:
        assert f"CASE_OK {c}" in r.stdout, (c, r.stdout[-2000:])


def test_parity_schedules_x_zero():
    """Core acceptance grid: 4 schedule x ZeRO x remat combinations."""
    _run_child(["1f1b-z0-full", "1f1b-z3-none", "gpipe-z1-full",
                "zb1f1b-z1-full"])


def test_parity_dualpipev_and_fused_overlap():
    """Split-backward schedules + fused (bucketed) ZeRO collectives
    lowering as one concatenated all_gather."""
    _run_child(["gpipe-z3-overlap", "dualpipev-z1-none",
                "dualpipev-z3-full"])


def test_parity_ep_and_offload():
    """Expert-parallel a2a (involutive round trip) and Offload d2h/h2d
    (on-device barrier fallback)."""
    _run_child(["1f1b-z2-offload", "1f1b-z1-ep"])


# ---------------------------------------------------------------------------
# in-process contracts (no faked devices needed)
# ---------------------------------------------------------------------------

def test_invalid_comm_order_rejected_before_tracing():
    """A plan that would hang a real cluster (mismatched collective
    dispatch order) must be rejected by the executor's constructor —
    before any tracing, and before the device-count check."""
    from repro.core import (CompiledProgram, ScheduleRejected, TrainingDAG,
                            ValueSpec)
    from repro.core.plan import ROLE_COLL, DevicePlan, GlobalPlan, Task
    from repro.runtime.spmd import SpmdExecutor

    dag = TrainingDAG()
    ag = dag.new_node(kind="comm", op="all_gather", name="ag",
                      devices=(0, 1), group=(0, 1), payload="param",
                      out_specs=[ValueSpec((8,))])
    ar = dag.new_node(kind="comm", op="all_reduce", name="ar",
                      devices=(0, 1), group=(0, 1), payload="grad",
                      out_specs=[ValueSpec((8,))])
    p0, p1 = DevicePlan(device=0), DevicePlan(device=1)
    p0.append(Task(ag.id, 0, ROLE_COLL, "zero"))
    p0.append(Task(ar.id, 0, ROLE_COLL, "zero"))
    p1.append(Task(ar.id, 1, ROLE_COLL, "zero"))  # flipped on rank 1
    p1.append(Task(ag.id, 1, ROLE_COLL, "zero"))
    plan = GlobalPlan(device_plans={0: p0, 1: p1}, priorities={},
                      devices=[0, 1])
    prog = CompiledProgram(dag=dag, plan=plan, params={}, schedule=())
    with pytest.raises(ScheduleRejected, match="dispatch order"):
        SpmdExecutor(prog)


def test_rank_program_extraction():
    """``GlobalPlan.rank_program``: each rank's extracted program covers
    exactly its tasks, follows the scheduler's global node order, and
    every per-stream queue is a subsequence of it."""
    import jax

    from helpers import (inputs_spec, make_mlp_forward, make_mlp_params)
    from repro.core import Mesh, Pipeline, Strategy, ZeRO, compile_training

    S, BATCH = 4, 8
    params = make_mlp_params(jax.random.PRNGKey(0), S)
    prog = compile_training(
        make_mlp_forward(S), params, inputs_spec(BATCH),
        strategy=Strategy(Mesh(pp=2, dp=2),
                          Pipeline("gpipe", n_mb=2) | ZeRO(stage=3)))
    plan = prog.plan
    assert plan.node_order, "scheduler must record its dispatch order"
    pos = {nid: i for i, nid in enumerate(plan.node_order)}
    for d in plan.devices:
        seq = plan.rank_program(d)
        assert {t.key for t in seq} == set(plan.plan_for(d).tasks)
        node_seq = [pos[t.node] for t in seq]
        assert node_seq == sorted(node_seq)
        # every stream queue is a subsequence of the rank program
        order = {t.key: i for i, t in enumerate(seq)}
        for keys in plan.plan_for(d).streams.values():
            idxs = [order[k] for k in keys]
            assert idxs == sorted(idxs)


def test_replay_matches_interpreter_exec_order():
    """The schedule-only replay (the SPMD executor's trace order) must
    reproduce the reference interpreter's dynamic dispatch order
    exactly — including the gather rate limiter's effect."""
    import jax

    from helpers import (inputs_spec, make_batch, make_mlp_forward,
                         make_mlp_params)
    from repro.core import Mesh, Pipeline, Strategy, ZeRO, compile_training
    from repro.runtime import Interpreter, replay_schedule

    S, BATCH = 4, 8
    params = make_mlp_params(jax.random.PRNGKey(0), S)
    prog = compile_training(
        make_mlp_forward(S), params, inputs_spec(BATCH),
        strategy=Strategy(Mesh(pp=2, dp=2),
                          Pipeline("1f1b", n_mb=2) | ZeRO(stage=3)))
    batch = make_batch(BATCH)
    ref = Interpreter(prog).run(batch)
    replay = replay_schedule(prog, batch)
    assert replay.exec_order == ref.exec_order
    assert len(replay.loss_order) == ref.stats["losses"]
