"""Timeline simulator tests: pipeline makespan sanity, comm/compute
overlap via streams (Fig 4), DualPipeV hiding EP all-to-alls (Fig 2/3),
and network interference between concurrent flows (the paper's measured
1.46x EP slowdown from background DP all-reduces)."""
import jax
import pytest

from helpers import (inputs_spec, make_mlp_forward, make_mlp_params,
                     make_moe_forward, raw_strategy)
from repro.core import F, Replicate, Shard, compile_training
from repro.core.schedules import build_rank_sequences, emit_directives
from repro.runtime.costmodel import CostModel
from repro.runtime.simulator import TimelineSimulator

jax.config.update("jax_platform_name", "cpu")

BATCH = 32
T_CHUNK = 10e-3


def const_cost(node):
    # ZeroBubble-style split backward: Bi + Bw together cost one B
    if node.dims.get("PASS") in ("Bi", "Bw"):
        return T_CHUNK / 2
    return T_CHUNK


def build_prog(kind, R, n_mb, forward_factory, n_stage, extra=None,
               batch=BATCH):
    S = {"gpipe": R, "1f1b": R}.get(kind, 2 * R)
    assert S == n_stage
    params = make_mlp_params(jax.random.PRNGKey(0), n_stage)
    fwd = forward_factory(n_stage)
    seqs = build_rank_sequences(kind, R, n_mb, n_stage)
    sched = emit_directives(kind, seqs,
                            device_groups=[[r] for r in range(R)],
                            n_stages=n_stage)
    if extra:
        sched = sched[:n_stage] + extra + sched[n_stage:]
    return compile_training(fwd, params, inputs_spec(batch),
                            strategy=raw_strategy(sched)), params


class TestMakespan:
    def test_gpipe_formula(self):
        """Near-zero comm: makespan ~ (M + R - 1) x (tF + tB)."""
        R, M = 4, 8
        prog, _ = build_prog("gpipe", R, M, make_mlp_forward, R)
        cost = CostModel(ici_bw=1e15, comm_latency=0.0)
        sim = TimelineSimulator(prog, cost,
                                chunk_seconds_override=const_cost)
        res = sim.run()
        ideal = (M + R - 1) * 2 * T_CHUNK
        assert res.makespan == pytest.approx(ideal, rel=0.25)

    def test_1f1b_not_slower_than_gpipe(self):
        R, M = 4, 8
        times = {}
        for kind in ("gpipe", "1f1b"):
            prog, _ = build_prog(kind, R, M, make_mlp_forward, R)
            cost = CostModel(ici_bw=1e15, comm_latency=0.0)
            res = TimelineSimulator(
                prog, cost, chunk_seconds_override=const_cost).run()
            times[kind] = res.makespan
        assert times["1f1b"] <= times["gpipe"] * 1.05


class TestStreamOverlap:
    def test_separate_reduce_stream_overlaps(self):
        """DP grad all-reduce on its own stream overlaps the remaining
        backward compute; on the compute stream it serializes (Fig 4b)."""
        n_stage = 6
        params = make_mlp_params(jax.random.PRNGKey(0), n_stage)
        fwd = make_mlp_forward(n_stage)
        spans = {}
        for name, stream in [("same", None), ("separate", "dp")]:
            sched = [Replicate(F(), devices=[0, 1], reduce_stream=stream)]
            prog = compile_training(fwd, params, inputs_spec(BATCH),
                                    strategy=raw_strategy(sched))
            # big grads so the ARs are comparable to compute time
            cost = CostModel(ici_bw=2e5, comm_latency=0.0)
            res = TimelineSimulator(
                prog, cost, chunk_seconds_override=const_cost).run()
            spans[name] = res.makespan
        assert spans["separate"] < spans["same"] * 0.9


class TestDualPipeV:
    def _moe(self, kind, R, n_mb, ici_bw):
        """Paper Fig. 1 layout: PP across stages, each PP rank group holds
        DP-2 for non-expert chunks and EP-2 for expert chunks."""
        from repro.core.schedules import rank_of_stage
        S = 2 * R
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        fwd = make_moe_forward(S, experts_every=2)
        for i in range(S - 1):
            if i % 2 == 1:
                k = jax.random.PRNGKey(100 + i)
                params[f"exp{i}"] = {
                    "w1": jax.random.normal(k, (16, 16)) * 0.1,
                    "w2": jax.random.normal(k, (16, 16)) * 0.1}
        groups = [[2 * r, 2 * r + 1] for r in range(R)]
        seqs = build_rank_sequences(kind, R, n_mb, S)
        sched = emit_directives(kind, seqs, device_groups=groups,
                                n_stages=S)
        extra = []
        for s in range(S):
            g = groups[rank_of_stage(kind, s, R, S)]
            extra.append(Replicate(F(**{"pp": s, "ep": "-"}), devices=g,
                                   reduce_stream="dp"))
            if s % 2 == 1 and s < S - 1:
                extra.append(Shard(F(**{"pp": s, "ep": "*"}), devices=g,
                                   stream="ep"))
        sched = sched[:S] + extra + sched[S:]
        prog = compile_training(
            fwd, params, inputs_spec(BATCH), strategy=raw_strategy(
                sched, split_backward=(kind == "dualpipev")))
        cost = CostModel(ici_bw=ici_bw, comm_latency=0.0)
        return TimelineSimulator(prog, cost,
                                 chunk_seconds_override=const_cost).run()

    def test_dualpipev_hides_a2a(self):
        """With expensive EP all-to-alls, DualPipeV's overlapped F+B pairs
        beat interleaved-1F1B (the paper's Fig 7 phenomenon; it reports
        10-13% over 1F1B baselines — at this comm/compute ratio the
        simulator shows ~11%)."""
        R, n_mb = 2, 8
        ici_bw = 2.5e4  # a2a ~ chunk-scale: EP comm on the critical path
        t_inter = self._moe("interleaved_1f1b", R, n_mb, ici_bw).makespan
        t_dual = self._moe("dualpipev", R, n_mb, ici_bw).makespan
        assert t_dual < t_inter * 0.95, (t_dual, t_inter)

    def test_dualpipev_parity_when_comm_free(self):
        """No comm cost -> the two schedules should be comparable."""
        R, n_mb = 2, 8
        t_inter = self._moe("interleaved_1f1b", R, n_mb, 1e15).makespan
        t_dual = self._moe("dualpipev", R, n_mb, 1e15).makespan
        assert t_dual <= t_inter * 1.1


class TestInterference:
    @staticmethod
    def _mini_prog(with_background_ar):
        """A bare DAG: one EP a2a, optionally one concurrent DP AR on a
        different stream over the same devices."""
        from repro.core import TrainingDAG, ValueSpec, build_plan
        from repro.core.compiler import CompiledProgram
        dag = TrainingDAG()
        dag.new_node(kind="comm", op="all_to_all", name="a2a",
                     devices=(0, 1), group=(0, 1), stream="ep",
                     payload="act", out_specs=[ValueSpec((1000,),
                                               "float32")])
        if with_background_ar:
            dag.new_node(kind="comm", op="all_reduce", name="ar",
                         devices=(0, 1), group=(0, 1), stream="dp",
                         payload="grad",
                         out_specs=[ValueSpec((4000,), "float32")])
        from repro.core.passes import assign_default_streams
        assign_default_streams(dag)
        plan = build_plan(dag)
        return CompiledProgram(dag=dag, plan=plan, params={}, schedule=())

    def test_background_allreduce_slows_a2a(self):
        """Concurrent flows share link bandwidth: an EP all-to-all slows
        down when a DP all-reduce runs in the background on its own
        stream (the paper measured a 1.46x slowdown; the fluid model
        gives 2x while both flows are active)."""
        cost = CostModel(ici_bw=1e6, comm_latency=0.0)
        solo = TimelineSimulator(self._mini_prog(False), cost).run()
        both = TimelineSimulator(self._mini_prog(True), cost).run()

        def a2a_time(res):
            rs = [r for r in res.records if r.name == "a2a"
                  and r.device == 0]
            return rs[0].end - rs[0].start

        assert a2a_time(both) > a2a_time(solo) * 1.3


class TestStraggler:
    def test_straggler_stretches_makespan(self):
        R, M = 4, 8
        prog, _ = build_prog("1f1b", R, M, make_mlp_forward, R)
        cost = CostModel(ici_bw=1e15, comm_latency=0.0)
        base = TimelineSimulator(
            prog, cost, chunk_seconds_override=const_cost).run().makespan
        slow = TimelineSimulator(
            prog, cost, chunk_seconds_override=const_cost,
            device_slowdown={1: 1.5}).run().makespan
        assert slow > base * 1.2
