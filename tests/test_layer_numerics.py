"""Numerics of the memory-sane formulations vs straightforward oracles:
flash attention (custom VJP) vs naive, chunked SSM scan vs step-by-step,
sort-based MoE dispatch vs one-hot einsum dispatch — values AND grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.attention import (chunked_attention, flash_attention_ref,
                                    naive_attention)
from repro.models import layers as L

jax.config.update("jax_platform_name", "cpu")
jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,block", [
        (2, 4, 4, 32, 32, 16, True, 8),
        (1, 8, 2, 64, 64, 32, True, 16),     # GQA
        (2, 4, 1, 16, 48, 8, False, 16),     # MQA, cross-ish, ragged
        (1, 2, 2, 33, 57, 8, True, 16),      # non-divisible shapes
    ])
    def test_fwd_bwd_match_naive(self, b, hq, hkv, sq, skv, d, causal,
                                 block):
        q = rand(0, (b, hq, sq, d))
        k = rand(1, (b, hkv, skv, d))
        v = rand(2, (b, hkv, skv, d))

        def f_flash(q, k, v):
            return jnp.sum(flash_attention_ref(
                q, k, v, causal=causal, block_kv=block) ** 2)

        def f_naive(q, k, v):
            return jnp.sum(naive_attention(q, k, v, causal=causal) ** 2)

        o1 = flash_attention_ref(q, k, v, causal=causal, block_kv=block)
        o2 = naive_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=2e-5)
        g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g1, g2):
            np.testing.assert_allclose(a, b_, atol=5e-5, rtol=5e-4)

    def test_window_matches_naive(self):
        q, k, v = (rand(i, (1, 2, 64, 16)) for i in range(3))
        o1 = flash_attention_ref(q, k, v, causal=True, window=16,
                                 block_kv=16)
        o2 = naive_attention(q, k, v, causal=True, window=16)
        np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=2e-5)

    def test_chunked_matches_naive(self):
        q, k, v = (rand(i, (2, 4, 48, 16)) for i in range(3))
        o1 = chunked_attention(q, k, v, causal=True, block_kv=16)
        o2 = naive_attention(q, k, v, causal=True)
        np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=2e-5)

    @settings(max_examples=15, deadline=None)
    @given(sq=st.integers(1, 40), skv=st.integers(1, 40),
           d=st.sampled_from([4, 8]), block=st.sampled_from([8, 16]),
           causal=st.booleans())
    def test_property_flash_equals_naive(self, sq, skv, d, block, causal):
        if causal and sq > skv:
            sq = skv  # causal prefill assumes q aligned to the cache end
        q = rand(10, (1, 2, sq, d))
        k = rand(11, (1, 2, skv, d))
        v = rand(12, (1, 2, skv, d))
        off = skv - sq if causal else 0
        o1 = flash_attention_ref(q, k, v, causal=causal, q_offset=off,
                                 block_kv=block)
        o2 = naive_attention(q, k, v, causal=causal, q_offset=off)
        np.testing.assert_allclose(o1, o2, atol=3e-5, rtol=3e-5)


class TestSSMScan:
    def _naive_scan(self, xz, dt, A, B, C, D, h0=None):
        bsz, s, c = xz.shape
        n = A.shape[1]
        h = (jnp.zeros((bsz, c, n), jnp.float32) if h0 is None
             else h0.astype(jnp.float32))
        ys = []
        for t in range(s):
            dA = jnp.exp(dt[:, t, :, None] * A)
            h = h * dA + (dt[:, t] * xz[:, t])[..., None] \
                * B[:, t][:, None, :]
            ys.append(jnp.einsum("bcn,bn->bc", h, C[:, t]))
        y = jnp.stack(ys, axis=1) + xz * D
        return y, h

    @pytest.mark.parametrize("s,chunk", [(16, 4), (24, 8), (7, 4)])
    def test_chunked_matches_naive(self, s, chunk):
        bsz, c, n = 2, 6, 4
        xz = rand(0, (bsz, s, c)) * 0.5
        dt = jax.nn.softplus(rand(1, (bsz, s, c)))
        A = -jnp.exp(rand(2, (c, n)) * 0.2)
        B = rand(3, (bsz, s, n)) * 0.5
        C = rand(4, (bsz, s, n)) * 0.5
        D = jnp.ones((c,))
        y1, h1 = L.ssm_scan_ref(xz, dt, A, B, C, D, chunk=chunk)
        y2, h2 = self._naive_scan(xz, dt, A, B, C, D)
        np.testing.assert_allclose(y1, y2, atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(h1, h2, atol=1e-5, rtol=1e-4)

    def test_grads_flow(self):
        bsz, s, c, n = 1, 8, 4, 3
        xz = rand(0, (bsz, s, c)) * 0.5
        dt = jax.nn.softplus(rand(1, (bsz, s, c)))
        A = -jnp.exp(rand(2, (c, n)) * 0.2)
        B = rand(3, (bsz, s, n)) * 0.5
        C = rand(4, (bsz, s, n)) * 0.5
        D = jnp.ones((c,))

        def loss(f):
            def inner(xz, A):
                y, _ = f(xz, dt, A, B, C, D)
                return jnp.sum(y ** 2)
            return inner
        g1 = jax.grad(loss(lambda *a: L.ssm_scan_ref(*a, chunk=4)),
                      argnums=(0, 1))(xz, A)
        g2 = jax.grad(loss(self._naive_scan), argnums=(0, 1))(xz, A)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)

    def test_state_continuation(self):
        """scan(x[:, :8]) then scan(x[:, 8:], h0) == scan(x) — decode
        correctness."""
        bsz, s, c, n = 1, 16, 4, 3
        xz = rand(0, (bsz, s, c)) * 0.5
        dt = jax.nn.softplus(rand(1, (bsz, s, c)))
        A = -jnp.exp(rand(2, (c, n)) * 0.2)
        B = rand(3, (bsz, s, n)) * 0.5
        C = rand(4, (bsz, s, n)) * 0.5
        D = jnp.ones((c,))
        y_full, h_full = L.ssm_scan_ref(xz, dt, A, B, C, D, chunk=4)
        y1, h1 = L.ssm_scan_ref(xz[:, :8], dt[:, :8], A, B[:, :8],
                                C[:, :8], D, chunk=4)
        y2, h2 = L.ssm_scan_ref(xz[:, 8:], dt[:, 8:], A, B[:, 8:],
                                C[:, 8:], D, h0=h1, chunk=4)
        np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1),
                                   y_full, atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(h2, h_full, atol=1e-5, rtol=1e-4)


class TestMoEDispatch:
    def _params(self, d=8, e=4, dex=16, shared=0):
        k = jax.random.PRNGKey(0)
        return L.init_moe(k, d, dex, e, shared, "swiglu", jnp.float32)

    def test_sort_matches_dense(self):
        d, e = 8, 4
        p = self._params(d=d, e=e)
        x = rand(5, (2, 8, d))
        kw = dict(n_experts=e, top_k=2, act="swiglu",
                  capacity_factor=8.0)  # ample capacity: no drops
        y1, a1 = L.moe_block(p, x, **kw)
        y2, a2 = L.moe_block_dense(p, x, **kw)
        np.testing.assert_allclose(y1, y2, atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(a1, a2, atol=1e-6)

    def test_sort_grads_match_dense(self):
        d, e = 8, 4
        p = self._params(d=d, e=e)
        x = rand(5, (2, 8, d))
        kw = dict(n_experts=e, top_k=2, act="swiglu", capacity_factor=8.0)

        def loss(fn):
            return lambda p, x: jnp.sum(fn(p, x, **kw)[0] ** 2)
        g1 = jax.grad(loss(L.moe_block))(p, x)
        g2 = jax.grad(loss(L.moe_block_dense))(p, x)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(a, b, atol=1e-4,
                                                    rtol=1e-3), g1, g2)

    def test_capacity_drops(self):
        """With capacity 0+, some tokens drop; outputs stay finite and
        the kept mass is <= full output mass."""
        d, e = 8, 4
        p = self._params(d=d, e=e)
        x = rand(5, (2, 8, d))
        y, _ = L.moe_block(p, x, n_experts=e, top_k=2, act="swiglu",
                           capacity_factor=0.25)
        assert np.all(np.isfinite(np.asarray(y)))
