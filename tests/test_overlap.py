"""Overlap engine (core/overlap.py): bucketed ZeRO collective fusion,
lookahead gather prefetch, bubble-aware scheduling — numerics parity
(bit-identical to the non-overlapped plan), memory honesty, and the
simulated step-time win the ISSUE demands."""
import jax
import numpy as np
import pytest

from helpers import (assert_grads_close, inputs_spec, make_batch,
                     make_mlp_forward, make_mlp_params, mlp_oracle,
                     raw_strategy)
from repro.core import F, OverlapConfig, Replicate, compile_training
from repro.core.schedules import (build_rank_sequences, emit_directives,
                                  rank_of_stage)
from repro.runtime import Interpreter
from repro.runtime.costmodel import CostModel
from repro.runtime.memory import timeline_peak_bytes
from repro.runtime.simulator import TimelineSimulator

jax.config.update("jax_platform_name", "cpu")

BATCH = 16
N_MB = 4


def build_zero_prog(kind="1f1b", R=2, n_mb=N_MB, dp=2, zero=3,
                    overlap=None, batch=BATCH):
    """PP(kind) x DP(dp) with ZeRO `zero` on every stage's DP group."""
    S = 2 * R
    params = make_mlp_params(jax.random.PRNGKey(0), S)
    fwd = make_mlp_forward(S)
    groups = [[r * dp + i for i in range(dp)] for r in range(R)]
    seqs = build_rank_sequences(kind, R, n_mb, S)
    sched = emit_directives(kind, seqs, device_groups=groups, n_stages=S)
    extra = [Replicate(F(pp=s, ep="-"),
                       devices=groups[rank_of_stage(kind, s, R, S)],
                       reduce_stream="dp", gather_stream="ag",
                       shard_grads=zero >= 2, shard_params=zero >= 3)
             for s in range(S)]
    sched = sched[:S] + extra + sched[S:]
    prog = compile_training(
        fwd, params, inputs_spec(batch), strategy=raw_strategy(
            sched, split_backward=(kind == "dualpipev"),
            overlap=overlap))
    return prog, params


ON = OverlapConfig(bucket_bytes=1 << 30, prefetch=4)


class TestBucketing:
    def test_fuses_within_budget(self):
        """Two stage buckets per rank fuse into one gather/reduce per
        (mb, pass) under a generous budget."""
        prog, _ = build_zero_prog(overlap=ON)
        assert prog.dag.meta["fused_gathers"] > 0
        assert prog.dag.meta["fused_reduce_scatters"] > 0
        # every fused node respects the byte budget
        for n in prog.dag.comms():
            if n.meta.get("fused"):
                assert n.total_out_bytes() <= ON.bucket_bytes

    def test_tiny_budget_disables_fusion(self):
        prog, _ = build_zero_prog(
            overlap=OverlapConfig(bucket_bytes=1, prefetch=4))
        assert prog.dag.meta["fused_gathers"] == 0
        assert prog.dag.meta["fused_reduce_scatters"] == 0

    def test_fused_members_distinct_buckets(self):
        """Fusion never merges same-bucket collectives of different
        microbatches (that would change summation order)."""
        prog, _ = build_zero_prog(overlap=ON)
        for n in prog.dag.comms():
            if not n.meta.get("fused"):
                continue
            if n.op == "all_gather":
                assert len(set(n.meta["buckets"])) == \
                    len(n.meta["buckets"])
            else:
                idents = [(m["bucket"], m.get("part", 0))
                          for m in n.meta["fused_members"]]
                assert len(set(idents)) == len(idents)


class TestParity:
    @pytest.mark.parametrize("kind", ["1f1b", "dualpipev"])
    def test_bit_identical_loss_and_grads(self, kind):
        """Acceptance: interpreter loss/grads of the overlapped plan are
        bit-identical to the non-overlapped plan (and match the
        single-device oracle)."""
        batch = make_batch(BATCH)
        runs = {}
        for tag, ov in (("off", OverlapConfig.off()), ("on", ON)):
            prog, params = build_zero_prog(kind=kind, overlap=ov)
            runs[tag] = (Interpreter(prog).run(batch), params)
        a, b = runs["off"][0], runs["on"][0]
        assert a.loss == b.loss
        assert set(a.grads) == set(b.grads)
        for bucket in a.grads:
            for u, v in zip(jax.tree_util.tree_leaves(a.grads[bucket]),
                            jax.tree_util.tree_leaves(b.grads[bucket])):
                assert np.array_equal(np.asarray(u), np.asarray(v))
        l, g = mlp_oracle(runs["on"][1], batch["x"], batch["y"], 4)
        assert b.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(b.grads, g)

    def test_zero2_reduce_scatter_parity(self):
        batch = make_batch(BATCH)
        res = {}
        for tag, ov in (("off", OverlapConfig.off()), ("on", ON)):
            prog, _ = build_zero_prog(zero=2, overlap=ov)
            res[tag] = Interpreter(prog).run(batch)
        assert res["off"].loss == res["on"].loss
        for bucket in res["off"].grads:
            for u, v in zip(
                    jax.tree_util.tree_leaves(res["off"].grads[bucket]),
                    jax.tree_util.tree_leaves(res["on"].grads[bucket])):
                assert np.array_equal(np.asarray(u), np.asarray(v))


class TestPrefetch:
    def test_overlap_hides_gathers(self):
        """Acceptance: >=10% simulated step-time reduction on a composed
        ZeRO-3 x PP config with comm comparable to compute."""
        cost = CostModel(ici_bw=2e5, comm_latency=0.0)
        times = {}
        for tag, ov in (("off", OverlapConfig.off()), ("on", ON)):
            prog, _ = build_zero_prog(overlap=ov)
            times[tag] = TimelineSimulator(
                prog, cost,
                chunk_seconds_override=lambda n: 1e-2).run().makespan
        assert times["on"] < 0.9 * times["off"], times

    def test_prefetch_depth_bounds_buffers(self):
        """Deeper prefetch trades memory for time: the estimated peak
        grows with k, and k=1 (JIT) matches the rate-limited lifetime."""
        cost = CostModel(ici_bw=2e5, comm_latency=0.0)
        peaks = {}
        for k in (1, 4):
            prog, _ = build_zero_prog(
                overlap=OverlapConfig(bucket_bytes=0, prefetch=k))
            res = TimelineSimulator(
                prog, cost, chunk_seconds_override=lambda n: 1e-2).run()
            peaks[k] = max(timeline_peak_bytes(prog, res.records).values())
        assert peaks[1] <= peaks[4]

    def test_gather_limit_exported_to_interpreter(self):
        prog, _ = build_zero_prog(overlap=ON)
        assert prog.dag.meta["gather_limit"] == ON.prefetch
        assert Interpreter(prog).gather_limit == ON.prefetch
        prog_off, _ = build_zero_prog(overlap=OverlapConfig.off())
        assert Interpreter(prog_off).gather_limit == 1
        # legacy plans keep the historical default
        prog_legacy, _ = build_zero_prog(overlap=None)
        assert Interpreter(prog_legacy).gather_limit == 2


class TestBubbleAware:
    @staticmethod
    def _two_collectives(bubble):
        """Collective X gated by a slow producer chain shares a stream
        with collective Y that is ready almost immediately; consumer
        order says X first.  Bubble-aware scheduling must let Y fill
        the bubble instead of queueing behind X (head-of-line)."""
        from repro.core import TrainingDAG, ValueSpec, build_plan
        from repro.core.compiler import CompiledProgram
        from repro.core.passes import assign_default_streams
        dag = TrainingDAG()
        a = [dag.new_node(kind="chunk", name=f"a{i}", devices=(0,),
                          out_specs=[ValueSpec((8,))]) for i in range(2)]
        b = [dag.new_node(kind="chunk", name=f"b{i}", devices=(1,),
                          out_specs=[ValueSpec((8,))]) for i in range(6)]
        for chain in (a, b):
            for u, v in zip(chain, chain[1:]):
                dag.add_temporal(u.id, v.id)
        big = ValueSpec((4000,), "float32")
        X = dag.new_node(kind="comm", op="all_gather", name="X",
                         devices=(0, 1), group=(0, 1), stream="s",
                         payload="act", out_specs=[big])
        Y = dag.new_node(kind="comm", op="all_gather", name="Y",
                         devices=(0, 1), group=(0, 1), stream="s",
                         payload="act", out_specs=[big])
        dag.add_edge(b[5].id, 0, X.id, 0, ValueSpec((8,)))
        dag.add_edge(a[1].id, 0, Y.id, 0, ValueSpec((8,)))
        cx = dag.new_node(kind="chunk", name="cx", devices=(0,),
                          out_specs=[ValueSpec((8,))])
        cy = dag.new_node(kind="chunk", name="cy", devices=(0,),
                          out_specs=[ValueSpec((8,))])
        dag.add_edge(X.id, 0, cx.id, 0, big)
        dag.add_edge(Y.id, 0, cy.id, 0, big)
        assign_default_streams(dag)
        dag.meta["bubble_aware"] = bubble
        plan = build_plan(dag)
        prog = CompiledProgram(dag=dag, plan=plan, params={},
                               schedule=())
        cost = CostModel(ici_bw=1e6, comm_latency=0.0)
        return TimelineSimulator(
            prog, cost, chunk_seconds_override=lambda n: 1e-3).run()

    def test_ready_comm_fills_bubble(self):
        t_plain = self._two_collectives(False).makespan
        t_bubble = self._two_collectives(True).makespan
        assert t_bubble < t_plain, (t_bubble, t_plain)

    def test_end_to_end_not_slower(self):
        """On the composed ZeRO-3 x PP program, bubble-aware anchoring
        never loses to consumer-order anchoring."""
        cost = CostModel(ici_bw=2e5, comm_latency=0.0)
        times = {}
        for bubble in (False, True):
            prog, _ = build_zero_prog(
                overlap=OverlapConfig(bucket_bytes=0, prefetch=4,
                                      bubble_aware=bubble))
            times[bubble] = TimelineSimulator(
                prog, cost,
                chunk_seconds_override=lambda n: 1e-2).run().makespan
        assert times[True] <= times[False] * 1.01, times


class TestInterpreterReuse:
    def test_repeated_runs_identical(self):
        """The hoisted per-run invariants must reset correctly: two
        run() calls on one Interpreter give identical results."""
        prog, _ = build_zero_prog(overlap=ON)
        interp = Interpreter(prog)
        batch = make_batch(BATCH)
        r1 = interp.run(batch)
        r2 = interp.run(batch)
        assert r1.loss == r2.loss
        assert r1.peak_bytes() == r2.peak_bytes()
        for bucket in r1.grads:
            for u, v in zip(jax.tree_util.tree_leaves(r1.grads[bucket]),
                            jax.tree_util.tree_leaves(r2.grads[bucket])):
                assert np.array_equal(np.asarray(u), np.asarray(v))


class TestTunerAxes:
    def test_zero3_candidates_carry_overlap_axes(self):
        from repro.tune import MeshSpec, SearchSpace
        from repro.configs import get_config
        space = SearchSpace(kinds=("1f1b",), mb_multipliers=(2,),
                            prefetch_depths=(1, 4), bucket_mbs=(0, 16))
        cands = list(space.candidates(get_config("qwen3-1b"),
                                      MeshSpec(pp=2, dp=2), 8192))
        z3 = [c for c in cands if c.zero == 3]
        assert {(c.prefetch, c.bucket_mb) for c in z3} == \
            {(1, 0), (1, 16), (4, 0), (4, 16)}
        assert all(c.prefetch == 0 and c.bucket_mb == 0
                   for c in cands if c.zero < 3)

    def test_candidate_overlap_round_trip(self):
        from repro.tune import Candidate
        from repro.tune.proxy import candidate_overlap
        c = Candidate(kind="1f1b", n_mb=4, zero=3, prefetch=4,
                      bucket_mb=16)
        ov = candidate_overlap(c)
        assert ov.prefetch == 4 and ov.bucket_bytes == 16 << 20
        assert candidate_overlap(
            Candidate(kind="1f1b", n_mb=4, zero=3)) is None
        assert Candidate.from_dict(c.to_dict()) == c
