"""Per-architecture smoke tests: instantiate a REDUCED config of the
same family, run one forward/train step and one decode step on CPU,
assert output shapes and no NaNs.  Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # full-family sweep; scripts/tier1.sh skips

from repro.configs import ARCHS, get_config
from repro.models import decode_step, init, params_count, prefill, train_loss

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 16


def smoke_batch(cfg, key=0):
    k = jax.random.PRNGKey(key)
    tokens = jax.random.randint(k, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens,
             "labels": jnp.where(
                 jnp.arange(S)[None] < S - 1,
                 jnp.roll(tokens, -1, axis=1), -1)}
    if cfg.n_enc_layers:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(k, 1), (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def reduced_models():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).reduced()
            params = init(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]
    return get


@pytest.mark.parametrize("name", ARCHS)
class TestSmoke:
    def test_train_step(self, name, reduced_models):
        cfg, params = reduced_models(name)
        batch = smoke_batch(cfg)
        loss, grads = jax.value_and_grad(
            lambda p: train_loss(cfg, p, batch))(params)
        assert np.isfinite(float(loss)), f"{name}: non-finite loss"
        leaves = jax.tree_util.tree_leaves(grads)
        assert leaves, f"{name}: no grads"
        for leaf in leaves:
            assert np.all(np.isfinite(np.asarray(leaf))), \
                f"{name}: NaN/inf grads"
        # loss should be near ln(vocab) at init (uniform predictions)
        assert 0.2 * np.log(cfg.vocab) < float(loss) < 3 * np.log(cfg.vocab)

    def test_decode_step(self, name, reduced_models):
        cfg, params = reduced_models(name)
        batch = smoke_batch(cfg)
        logits, cache = prefill(cfg, params, batch, max_seq=S + 8)
        assert logits.shape == (B, 1, cfg.vocab)
        if cfg.n_enc_layers:
            # fill the cross-attn cache from encoder output for decode
            pass
        tok = jnp.argmax(logits[:, -1], axis=-1).reshape(B, 1)
        logits2, cache2 = decode_step(cfg, params, tok, cache)
        assert logits2.shape == (B, 1, cfg.vocab)
        assert int(cache2["len"]) == int(cache["len"]) + 1
        assert np.all(np.isfinite(np.asarray(logits2)))


class TestParamCount:
    @pytest.mark.parametrize("name", ARCHS)
    def test_analytic_matches_actual(self, name):
        """params_count() (used for roofline MODEL_FLOPS) must match the
        actually-initialized reduced model within 2%."""
        cfg = get_config(name).reduced()
        params = init(cfg, jax.random.PRNGKey(0))
        actual = sum(l.size for l in jax.tree_util.tree_leaves(params))
        analytic = params_count(cfg)
        assert abs(actual - analytic) / actual < 0.02, \
            (name, actual, analytic)

    def test_full_config_scale(self):
        """Full-config param counts should be near the names' scales."""
        expect = {"qwen2.5-32b": 32e9, "dbrx-132b": 132e9,
                  "falcon-mamba-7b": 7e9, "minicpm-2b": 2.7e9,
                  "deepseek-moe-16b": 16e9, "granite-20b": 20e9,
                  "zamba2-2.7b": 2.7e9, "qwen2-vl-7b": 7e9}
        for name, target in expect.items():
            n = params_count(get_config(name))
            assert 0.5 * target < n < 1.8 * target, \
                f"{name}: {n/1e9:.1f}B vs expected ~{target/1e9:.0f}B"
