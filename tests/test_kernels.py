"""Pallas kernel validation (interpret=True): shape/dtype sweeps with
assert_allclose against the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.slow  # interpret-mode grids; scripts/tier1.sh skips
from repro.kernels.flash_attention import flash_attention_fwd_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(key), shape) * scale
    return x.astype(dtype)


TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(4, 64), (3, 5, 128), (130, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        x = rand(0, shape, dtype)
        w = rand(1, shape[-1:], dtype, 0.5) + 1.0
        got = rmsnorm_pallas(x, w, interpret=True)
        want = ref.rmsnorm_ref(x, w)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype])


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal", [
        (1, 2, 2, 32, 32, 16, True),
        (2, 4, 1, 64, 64, 32, True),      # MQA
        (1, 8, 2, 64, 128, 16, True),     # GQA, cross lengths
        (1, 2, 2, 32, 48, 16, False),
        (1, 2, 2, 40, 72, 8, True),       # non-divisible by blocks
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_fwd_matches_naive(self, b, hq, hkv, sq, skv, d, causal,
                               dtype):
        q = rand(0, (b, hq, sq, d), dtype)
        k = rand(1, (b, hkv, skv, d), dtype)
        v = rand(2, (b, hkv, skv, d), dtype)
        off = skv - sq if causal else 0
        got = flash_attention_fwd_pallas(q, k, v, causal=causal,
                                         q_offset=off, block_q=16,
                                         block_kv=16, interpret=True)
        want = ref.naive_attention(q, k, v, causal=causal, q_offset=off)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype])

    def test_custom_vjp_grads(self):
        q = rand(0, (1, 2, 32, 16))
        k = rand(1, (1, 2, 32, 16))
        v = rand(2, (1, 2, 32, 16))

        def f(fn):
            return lambda q, k, v: jnp.sum(fn(q, k, v) ** 2)
        g1 = jax.grad(f(lambda *a: ops.flash_attention(*a, block_kv=16)),
                      argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f(lambda *a: ref.naive_attention(*a, causal=True)),
                      argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-4)


class TestMoEGMM:
    @pytest.mark.parametrize("e,cap,d,f", [
        (4, 32, 64, 128), (2, 16, 32, 32), (8, 130, 64, 96)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, e, cap, d, f, dtype):
        x = rand(0, (e, cap, d), dtype, 0.3)
        w = rand(1, (e, d, f), dtype, 0.3)
        got = moe_gmm_pallas(x, w, interpret=True)
        want = ref.moe_gmm_ref(x, w)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
            rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


class TestMambaScanKernel:
    @pytest.mark.parametrize("b,s,c,n,bc", [
        (1, 16, 8, 4, 4), (2, 24, 16, 8, 8), (1, 8, 6, 4, 4)])
    def test_matches_ref(self, b, s, c, n, bc):
        xz = rand(0, (b, s, c), scale=0.5)
        dt = jax.nn.softplus(rand(1, (b, s, c)))
        A = -jnp.exp(rand(2, (c, n), scale=0.2))
        B = rand(3, (b, s, n), scale=0.5)
        C = rand(4, (b, s, n), scale=0.5)
        D = jnp.ones((c,))
        got_y, got_h = mamba_scan_pallas(xz, dt, A, B, C, D,
                                         block_c=bc, interpret=True)
        want_y, want_h = ref.ssm_scan_ref(xz, dt, A, B, C, D, chunk=8)
        np.testing.assert_allclose(got_y, want_y, atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(got_h, want_h, atol=1e-5, rtol=1e-4)

    def test_state_continuation(self):
        b, s, c, n = 1, 16, 8, 4
        xz = rand(0, (b, s, c), scale=0.5)
        dt = jax.nn.softplus(rand(1, (b, s, c)))
        A = -jnp.exp(rand(2, (c, n), scale=0.2))
        B = rand(3, (b, s, n), scale=0.5)
        C = rand(4, (b, s, n), scale=0.5)
        D = jnp.ones((c,))
        y_full, h_full = mamba_scan_pallas(xz, dt, A, B, C, D,
                                           block_c=4, interpret=True)
        y1, h1 = mamba_scan_pallas(xz[:, :8], dt[:, :8], A, B[:, :8],
                                   C[:, :8], D, block_c=4, interpret=True)
        y2, h2 = mamba_scan_pallas(xz[:, 8:], dt[:, 8:], A, B[:, 8:],
                                   C[:, 8:], D, h0=h1, block_c=4,
                                   interpret=True)
        np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                                   atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(h2, h_full, atol=1e-5, rtol=1e-4)


class TestRegistry:
    def test_register_swaps_model_impls(self):
        """A reduced model forward must agree with and without the
        Pallas kernels installed."""
        from repro.configs import get_config
        from repro.models import init, train_loss
        cfg = get_config("qwen1.5-0.5b").reduced()
        params = init(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                    cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}
        base = float(train_loss(cfg, params, batch))
        ops.register_kernels()
        try:
            with_kernels = float(train_loss(cfg, params, batch))
        finally:
            ops.unregister_kernels()
        assert abs(base - with_kernels) < 1e-4, (base, with_kernels)
