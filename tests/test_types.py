"""Semantic certifier (repro.analysis.types / equiv, DESIGN.md §16).

- clean plans across schedules x ZeRO x remat typecheck with zero
  diagnostics — the semantic layer is exact, not heuristic;
- golden hand-mutated plans each produce their exact PIPER02x code:
  dtype flip on an edge (PIPER020), dropped remat stash edge
  (PIPER021), wrong gather group (PIPER022), corrupted fused-gather
  member spec (PIPER023), lost microbatch token / non-conserving
  mb_split (PIPER024), mismatched p2p specs in a hand-edited rank
  program (PIPER025);
- the dataflow fingerprint is invariant across every certified rewrite
  (remat full/none, overlap on/off, offload on/off, mb_split) and a
  corrupted pass is rejected at its own ``run_all`` boundary with
  PIPER026 under REPRO_CHECK_PASSES=1 (on suite-wide via conftest);
- ``GlobalPlan.rank_signature`` extracts per-rank typed interfaces and
  the pairwise check (the MPMD-readiness gate) holds on clean plans.
"""
import copy

import jax
import pytest
from helpers import inputs_spec, make_mlp_forward, make_mlp_params

from repro.analysis import (PlanVerificationError, analyze,
                            certify_equivalent, dataflow_fingerprint,
                            rank_interface_diagnostics, rank_signature,
                            typecheck)
from repro.core import passes
from repro.core.compiler import compile_training
from repro.core.dag import ValueSpec
from repro.core.strategy import (Mesh, Offload, Overlap, Pipeline, Remat,
                                 Strategy, ZeRO)

S, D, BATCH = 4, 16, 8

SEMANTIC_CODES = {f"PIPER{i:03d}" for i in range(20, 27)}


def compile_mlp(sched="1f1b", zero=3, n_mb=4, overlap=False, remat=None,
                offload=False, mb_split=None, **kw):
    frags = Pipeline(sched, n_mb=n_mb, mb_split=mb_split) | ZeRO(stage=zero)
    if overlap:
        frags = frags | Overlap(prefetch=2, bucket_mb=64)
    if remat is not None:
        frags = frags | Remat(remat)
    if offload:
        frags = frags | Offload(depth=1)
    params = make_mlp_params(jax.random.PRNGKey(0), S, D)
    return compile_training(make_mlp_forward(S), params,
                            inputs_spec(BATCH, D),
                            strategy=Strategy(Mesh(pp=2, dp=2), frags),
                            **kw)


# ---------------------------------------------------------------------------
# clean plans: the typechecker is exact
# ---------------------------------------------------------------------------

class TestCleanPlans:
    @pytest.mark.parametrize("sched", ["1f1b", "gpipe", "dualpipev"])
    @pytest.mark.parametrize("zero", [0, 3])
    @pytest.mark.parametrize("remat", [None, "none"])
    def test_grid_typechecks_clean(self, sched, zero, remat):
        prog = compile_mlp(sched, zero, remat=remat)
        report = analyze(prog, depth="quick")
        assert report.ok, report.format_text()
        assert not (set(report.codes()) & SEMANTIC_CODES)
        assert report.meta["types"] is True

    def test_overlap_offload_plan_typechecks_clean(self):
        prog = compile_mlp(overlap=True, remat="none", offload=True)
        report = analyze(prog, depth="quick")
        assert report.ok, report.format_text()
        assert typecheck(prog.dag) == []
        assert rank_interface_diagnostics(prog.dag, prog.plan) == []

    def test_types_flag_off_skips_semantic_layer(self):
        prog = compile_mlp()
        report = analyze(prog, depth="quick", types=False)
        assert report.meta["types"] is False
        # corrupting an edge dtype goes unseen only when types=False
        mut = copy.deepcopy(prog)
        _flip_edge_dtype(mut.dag)
        assert "PIPER020" not in analyze(mut, types=False).codes()
        assert "PIPER020" in analyze(mut).codes()


# ---------------------------------------------------------------------------
# golden mutations — one exact code each
# ---------------------------------------------------------------------------

def _flip_edge_dtype(dag):
    for e in dag.edges:
        src, dst = dag.nodes.get(e.src), dag.nodes.get(e.dst)
        if (e.dst_in >= 0 and src is not None and dst is not None
                and src.is_chunk and dst.is_chunk):
            dag.edges.remove(e)
            dag.edges.append(e.moved(spec=ValueSpec(e.spec.shape,
                                                    "bfloat16")))
            return e
    raise AssertionError("no chunk-to-chunk data edge found")


class TestGoldenMutations:
    def test_dtype_flip_on_edge_is_piper020(self):
        mut = copy.deepcopy(compile_mlp())
        e = _flip_edge_dtype(mut.dag)
        report = analyze(mut, depth="quick")
        d = report.by_code("PIPER020")
        assert d, report.format_text()
        assert e.src in d[0].nodes and e.dst in d[0].nodes
        assert "bfloat16" in d[0].message

    def test_dropped_remat_stash_edge_is_piper021(self):
        mut = copy.deepcopy(compile_mlp(remat="none"))
        dag = mut.dag
        stash = None
        for e in dag.edges:
            src = dag.nodes.get(e.src)
            dst = dag.nodes.get(e.dst)
            if (src is not None and dst is not None and src.is_chunk
                    and src.meta.get("n_res")
                    and dst.is_chunk
                    and dst.dims.get("PASS") in ("B", "Bi", "Bw")
                    and 0 <= e.dst_in < dst.meta.get("n_inputs", 0)
                    - dst.meta.get("n_cots", 0)):
                stash = e
                break
        assert stash is not None, "no remat stash edge found"
        dag.edges.remove(stash)
        report = analyze(mut, depth="quick")
        d = report.by_code("PIPER021")
        assert d, report.format_text()
        hit = [x for x in d if stash.dst in x.nodes]
        assert hit and "unfed" in hit[0].message
        # provenance names the rewriting pass
        assert any("pass:apply_remat" in p
                   for x in hit for p in x.provenance)

    def test_wrong_gather_group_is_piper022(self):
        mut = copy.deepcopy(compile_mlp(zero=3))
        gather = next(n for n in mut.dag.comms()
                      if n.op == "all_gather" and n.payload == "param")
        gather.group = (gather.group[0],)
        report = analyze(mut, depth="quick")
        d = report.by_code("PIPER022")
        assert d, report.format_text()
        assert gather.id in d[0].nodes
        assert "replica group" in d[0].message
        # blames the ZeRO directive that introduced the gather
        assert any("ZeRO" in p for p in d[0].provenance)

    def test_corrupt_fused_gather_member_is_piper023(self):
        mut = copy.deepcopy(compile_mlp(zero=3, overlap=True))
        fused = [n for n in mut.dag.comms()
                 if n.op == "all_gather" and n.meta.get("fused")]
        assert fused, "overlap engine fused no gathers"
        n = fused[0]
        # wrong member size after fusion: slot typed at shard size
        shard = ValueSpec((max(n.out_specs[0].shape[0] // 2, 1),),
                          n.out_specs[0].dtype)
        n.out_specs[0] = shard
        report = analyze(mut, depth="quick")
        d = report.by_code("PIPER023")
        assert d, report.format_text()
        assert n.id in d[0].nodes
        # provenance blames the fusing pass
        assert any("pass:apply_overlap" in p for p in d[0].provenance)

    def test_lost_microbatch_token_is_piper024(self):
        mut = copy.deepcopy(compile_mlp())
        dag = mut.dag
        mb = dag.meta["microbatch_inputs"]
        base, info = next(iter(mb.items()))
        victim = info["names"][-1]
        del dag.inputs[victim]
        report = analyze(mut, depth="quick")
        d = report.by_code("PIPER024")
        assert d, report.format_text()
        assert victim in d[0].message
        assert d[0].details["base"] == base

    def test_non_conserving_mb_split_is_piper024(self):
        mut = copy.deepcopy(compile_mlp(n_mb=4))
        mut.dag.meta["mb_split"] = {0: 2, 1: 1}   # sums to 3, not 4
        report = analyze(mut, depth="quick")
        d = report.by_code("PIPER024")
        assert d, report.format_text()
        assert "re-assigns microbatches" in d[0].message

    def test_mismatched_p2p_specs_is_piper025(self):
        mut = copy.deepcopy(compile_mlp())
        dag = mut.dag
        p2p = next(n for n in dag.comms() if n.op == "p2p")
        # hand-edit the receiving rank's program: its consumers now
        # expect a different dtype than the sender supplies
        for e in list(dag.edges):
            if e.src == p2p.id and e.dst_in >= 0:
                dag.edges.remove(e)
                dag.edges.append(e.moved(spec=ValueSpec(e.spec.shape,
                                                        "bfloat16")))
        report = analyze(mut, depth="quick")
        d = report.by_code("PIPER025")
        assert d, report.format_text()
        assert "p2p interface mismatch" in d[0].message
        assert "bfloat16" in d[0].message


# ---------------------------------------------------------------------------
# translation validation (PIPER026)
# ---------------------------------------------------------------------------

class TestTranslationValidation:
    def test_fingerprint_invariant_across_remat(self):
        a = dataflow_fingerprint(compile_mlp(remat=None).dag)
        b = dataflow_fingerprint(compile_mlp(remat="none").dag)
        assert a == b and a.digest() == b.digest()

    def test_fingerprint_invariant_across_overlap_and_offload(self):
        a = dataflow_fingerprint(compile_mlp(remat="none").dag)
        b = dataflow_fingerprint(
            compile_mlp(remat="none", overlap=True, offload=True).dag)
        assert a == b

    def test_fingerprint_invariant_across_mb_split(self):
        a = dataflow_fingerprint(compile_mlp().dag)
        b = dataflow_fingerprint(compile_mlp(mb_split={0: 3, 1: 1}).dag)
        assert a == b

    def test_schedules_share_dataflow_but_zero_stages_do_not(self):
        f1 = dataflow_fingerprint(compile_mlp("1f1b", 3).dag)
        fg = dataflow_fingerprint(compile_mlp("gpipe", 3).dag)
        f0 = dataflow_fingerprint(compile_mlp("1f1b", 0).dag)
        assert f1 == fg           # scheduling-independent by design
        assert f1 != f0           # ZeRO-3 changes the reduction op

    def test_certify_reports_piper026_with_the_pass_name(self):
        prog = compile_mlp()
        before = dataflow_fingerprint(prog.dag)
        mut = copy.deepcopy(prog)
        victim = next(n for n in mut.dag.chunks()
                      if n.dims.get("PASS") == "F")
        victim.name = victim.name + "_corrupted"
        after = dataflow_fingerprint(mut.dag)
        diags = certify_equivalent(before, after, "elide_allgathers")
        assert len(diags) == 1
        d = diags[0]
        assert d.code == "PIPER026"
        assert "elide_allgathers" in d.message
        assert d.details["pass"] == "elide_allgathers"
        assert d.details["diff"]
        assert certify_equivalent(before, before, "noop") == []

    def test_corrupted_pass_rejected_at_its_boundary(self, monkeypatch):
        # a pass that silently rewrites a chunk's identity must be
        # rejected at ITS boundary by run_all's translation validation
        real = passes.elide_allgathers

        def corrupting(dag):
            real(dag)
            victim = next(n for n in dag.chunks()
                          if n.dims.get("PASS") == "F")
            victim.name = victim.name + "_oops"

        monkeypatch.setattr(passes, "elide_allgathers", corrupting)
        monkeypatch.setenv("REPRO_CHECK_PASSES", "1")
        with pytest.raises(PlanVerificationError) as exc:
            compile_mlp()
        report = exc.value.report
        assert report.codes() == ["PIPER026"]
        assert report.meta["pass"] == "elide_allgathers"
        assert "elide_allgathers" in report.diagnostics[0].message

    def test_whole_pipeline_compiles_under_check_passes(self, monkeypatch):
        # the acceptance bar: every certified pass, all at once, under
        # pass-boundary translation validation
        monkeypatch.setenv("REPRO_CHECK_PASSES", "1")
        prog = compile_mlp(remat="none", overlap=True, offload=True,
                           mb_split={0: 3, 1: 1})
        assert analyze(prog, depth="deep").ok


# ---------------------------------------------------------------------------
# per-rank interface signatures (MPMD readiness)
# ---------------------------------------------------------------------------

class TestRankSignatures:
    def test_signatures_pair_up_across_ranks(self):
        prog = compile_mlp()
        sigs = {d: rank_signature(prog.dag, prog.plan, d)
                for d in prog.plan.devices}
        sends = sum(len(s["sends"]) for s in sigs.values())
        recvs = sum(len(s["recvs"]) for s in sigs.values())
        assert sends == recvs > 0
        for d, sig in sigs.items():
            for (peer, _nid, spec) in sig["sends"]:
                assert spec is not None
                assert any(p == d and s == spec
                           for (p, _n, s) in sigs[peer]["recvs"])
        assert rank_interface_diagnostics(prog.dag, prog.plan) == []

    def test_collective_sequences_agree_groupwise(self):
        prog = compile_mlp(zero=3, overlap=True)
        sigs = {d: rank_signature(prog.dag, prog.plan, d)
                for d in prog.plan.devices}
        by_group = {}
        for d, sig in sigs.items():
            for (group, nid, op, payload, specs) in sig["collectives"]:
                by_group.setdefault(group, {}).setdefault(d, []).append(
                    (nid, op, payload, specs))
        for group, per_rank in by_group.items():
            seqs = [per_rank.get(r, []) for r in group]
            assert all(s == seqs[0] for s in seqs[1:])

    def test_plan_method_delegates(self):
        prog = compile_mlp()
        d = prog.plan.devices[0]
        assert prog.plan.rank_signature(d, prog.dag) == \
            rank_signature(prog.dag, prog.plan, d)


# ---------------------------------------------------------------------------
# pass provenance rendering
# ---------------------------------------------------------------------------

class TestPassProvenance:
    def test_pass_inserted_nodes_render_their_pass(self):
        from repro.analysis import node_provenance
        prog = compile_mlp(remat="none", overlap=True, offload=True)
        dag = prog.dag
        rendered = {node_provenance(dag, nid) for nid in dag.nodes}
        assert any("pass:apply_offload" in r for r in rendered)
        assert any("pass:apply_overlap" in r for r in rendered)
        assert any("pass:apply_remat" in r for r in rendered)
        assert any("insert_p2p" in r for r in rendered)

    def test_merged_reduce_renders_merge_pass(self):
        from repro.analysis import node_provenance
        prog = compile_mlp(zero=0)   # unsharded grads -> merged reduces
        dag = prog.dag
        merged = [n for n in dag.comms() if n.meta.get("accumulated")]
        assert merged
        assert "pass:merge_grad_reduces" in node_provenance(
            dag, merged[0].id)
