"""Static plan verifier (repro.analysis, DESIGN.md §15).

- clean plans across schedules x ZeRO stages (and the overlap engine)
  verify clean at deep depth — the abstract executor replays every task;
- golden hand-mutated plans each produce their expected PIPER code with
  directive provenance: dropped recv (PIPER003/005), reordered
  collective (PIPER004), duplicated grad reduce — double-freed stash
  (PIPER007), reduce torn off its stream — racy pair (PIPER010), a
  full-param buffer with no releasing consumer (PIPER008);
- the PR 4 regression: all-gathers fused across the F->B boundary
  starve the gather rate limiter — rejected *statically* with PIPER002
  naming the semaphore cycle;
- the scheduler's comm-order validation now routes through the verifier
  (PlanVerificationError carries the report; legacy message substrings
  preserved);
- compile_training embeds the quick subset; the lint CLI surfaces.
"""
import copy
import json

import jax
import pytest
from helpers import inputs_spec, make_mlp_forward, make_mlp_params

from repro.analysis import CODES, PlanVerificationError, analyze
from repro.analysis.abstract import AbstractExecutor, Execution, StuckState
from repro.core.compiler import compile_training
from repro.core.plan import ScheduleRejected
from repro.core.scheduler import build_plan, validate_comm_order
from repro.core.strategy import Mesh, Overlap, Pipeline, Strategy, ZeRO

S, D, BATCH = 4, 16, 8


def compile_mlp(sched="1f1b", zero=3, n_mb=4, overlap=False, **kw):
    frags = Pipeline(sched, n_mb=n_mb) | ZeRO(stage=zero)
    if overlap:
        frags = frags | Overlap(prefetch=2, bucket_mb=64)
    params = make_mlp_params(jax.random.PRNGKey(0), S, D)
    return compile_training(make_mlp_forward(S), params,
                            inputs_spec(BATCH, D),
                            strategy=Strategy(Mesh(pp=2, dp=2), frags),
                            **kw)


# ---------------------------------------------------------------------------
# clean plans
# ---------------------------------------------------------------------------

class TestCleanPlans:
    @pytest.mark.parametrize("sched,zero", [
        ("1f1b", 0), ("1f1b", 3), ("gpipe", 0), ("gpipe", 3),
        ("dualpipev", 0), ("dualpipev", 3)])
    def test_deep_verifies_clean(self, sched, zero):
        prog = compile_mlp(sched, zero)
        report = analyze(prog, depth="deep")
        assert report.ok, report.format_text()
        assert report.diagnostics == []
        assert "completed" in report.meta["abstract"]

    def test_overlap_engine_plan_clean(self):
        prog = compile_mlp("1f1b", 3, overlap=True)
        report = analyze(prog, depth="deep")
        assert report.ok, report.format_text()
        # PIPER009 is a warning, so assert it separately: the abstract
        # ledger and the static estimator must agree on transient peaks
        assert report.by_code("PIPER009") == []

    def test_abstract_executor_replays_every_task(self):
        prog = compile_mlp("1f1b", 3)
        outcome = AbstractExecutor(prog).run()
        assert isinstance(outcome, Execution)
        total = sum(p.n_tasks()
                    for p in prog.plan.device_plans.values())
        assert len(outcome.exec_order) == total
        assert outcome.events == []
        assert outcome.leftover_values == []
        assert outcome.leftover_buffers == []

    def test_clean_plan_survives_tight_gather_limit(self):
        # per-pass gathers release promptly: even one permit suffices
        prog = compile_mlp("1f1b", 3)
        report = analyze(prog, depth="deep", gather_limit=1)
        assert report.ok, report.format_text()


# ---------------------------------------------------------------------------
# golden mutations
# ---------------------------------------------------------------------------

def drop_one_recv(plan):
    for _d, dp in sorted(plan.device_plans.items()):
        for key in list(dp.tasks):
            if key[2] == "recv":
                del dp.tasks[key]
                for keys in dp.streams.values():
                    if key in keys:
                        keys.remove(key)
                return key
    raise AssertionError("no recv task found")


class TestGoldenMutations:
    def test_dropped_recv_is_unsatisfiable_wait(self):
        prog = compile_mlp()
        mut = copy.deepcopy(prog)
        key = drop_one_recv(mut.plan)
        report = analyze(mut, depth="deep")
        assert not report.ok
        codes = set(report.codes())
        assert "PIPER003" in codes    # consumer waits on the missing task
        assert "PIPER005" in codes    # send order no longer matches recvs
        d3 = report.by_code("PIPER003")[0]
        assert key[0] in d3.nodes
        assert "exists in no device plan" in d3.message
        # provenance names the pass that created the p2p
        assert any("insert_p2p" in p for p in d3.provenance)

    def test_reordered_collective_breaks_dispatch_order(self):
        prog = compile_mlp()
        mut = copy.deepcopy(prog)
        for dp in mut.plan.device_plans.values():
            for keys in dp.streams.values():
                colls = [i for i, k in enumerate(keys) if k[2] == "coll"]
                if len(colls) >= 2:
                    i, j = colls[0], colls[1]
                    keys[i], keys[j] = keys[j], keys[i]
                    report = analyze(mut, depth="quick")
                    d4 = report.by_code("PIPER004")
                    assert d4, report.format_text()
                    assert "dispatch order" in d4[0].message
                    assert "first divergence" in d4[0].message
                    assert d4[0].provenance
                    return
        raise AssertionError("no stream with two collectives")

    def test_duplicated_reduce_double_frees_the_stash(self):
        prog = compile_mlp("1f1b", 0, analyze="off")
        dag = prog.dag
        ar = next(n for n in dag.comms()
                  if n.op == "all_reduce" and n.payload == "grad")
        with dag.origin("test_duplicate_reduce"):
            dup = dag.new_node(
                kind="comm", op="all_reduce", name=f"dup_{ar.name}",
                dims=dict(ar.dims), devices=ar.devices, stream=ar.stream,
                group=ar.group, payload="grad",
                out_specs=list(ar.out_specs),
                meta={"bucket": ar.meta.get("bucket"),
                      "accumulated": ar.meta.get("accumulated")})
            for e in dag.in_edges(ar.id):
                dag.add_edge(e.src, e.src_out, dup.id, e.dst_in, e.spec)
            dag.add_temporal(ar.id, dup.id)
        prog.plan = build_plan(dag)
        report = analyze(prog, depth="deep")
        d7 = report.by_code("PIPER007")
        assert d7, report.format_text()
        assert "empty accumulation stash" in d7[0].message
        assert any("test_duplicate_reduce" in p for p in d7[0].provenance)

    def test_unordered_reduce_is_a_stream_race(self):
        prog = compile_mlp("1f1b", 0)
        mut = copy.deepcopy(prog)
        ar = next(n for n in mut.dag.comms()
                  if n.op == "all_reduce" and n.payload == "grad"
                  and n.meta.get("accumulated"))
        for d, dp in mut.plan.device_plans.items():
            key = (ar.id, d, "coll")
            if key not in dp.tasks:
                continue
            t = dp.tasks[key]
            # tear the reduce off its stream onto an unordered one and
            # drop its deps — the classic lost-ordering-edge bug
            for keys in dp.streams.values():
                if key in keys:
                    keys.remove(key)
            t.stream = "rogue_reduce"
            t.deps = []
            dp.streams.setdefault("rogue_reduce", []).append(key)
        report = analyze(mut, depth="quick")
        d10 = report.by_code("PIPER010")
        assert d10, report.format_text()
        assert "no ordering edge" in d10[0].message
        assert d10[0].details["reduce_stream"] == "rogue_reduce"
        assert any("autodiff" in p for p in d10[0].provenance)
        # deep agrees: the reduce fires before any backward wrote grads
        deep = analyze(mut, depth="deep")
        assert "PIPER007" in deep.codes()

    def test_unreleased_fullparam_leaks(self):
        prog = compile_mlp("1f1b", 3)
        mut = copy.deepcopy(prog)
        victim = next(
            n for n in mut.dag.nodes.values()
            if n.is_chunk and n.meta.get("param_from_comm") is not None
            and n.dims.get("PASS") == "B")
        victim.meta.pop("param_from_comm")
        report = analyze(mut, depth="deep")
        d8 = report.by_code("PIPER008")
        assert d8, report.format_text()
        assert any(d.details.get("buffer_kind") == "fullparam"
                   for d in d8)


# ---------------------------------------------------------------------------
# the PR 4 regression, statically
# ---------------------------------------------------------------------------

class TestGatherFusionRegression:
    def _fuse_gathers_across_fb(self, prog):
        """Re-create the PR 4 bug: backward chunks reuse the *forward*
        gather's full-param buffer, so the buffer stays live across the
        whole F->B window and the rate limiter starves."""
        dag = prog.dag
        fwd_gather = {}
        for n in dag.nodes.values():
            g = n.meta.get("param_from_comm")
            if g is not None and n.is_chunk and n.dims.get("PASS") == "F":
                fwd_gather[(n.bucket, n.dims.get("MB"))] = g
        doomed = set()
        for n in dag.nodes.values():
            g = n.meta.get("param_from_comm")
            if g is None or not n.is_chunk:
                continue
            if n.dims.get("PASS") in ("B", "Bi", "Bw"):
                fg = fwd_gather.get((n.bucket, n.dims.get("MB")))
                if fg is not None and fg != g:
                    doomed.add(g)
                    n.meta["param_from_comm"] = fg
        for g in doomed:
            dag.remove_node(g)
        prog.plan = build_plan(dag)
        return prog

    def test_fb_fused_gathers_deadlock_on_rate_limiter(self):
        prog = self._fuse_gathers_across_fb(compile_mlp("1f1b", 3))
        report = analyze(prog, depth="deep", gather_limit=1)
        d2 = report.by_code("PIPER002")
        assert d2, report.format_text()
        msg = d2[0].message
        assert "rate-limiter" in msg and "gather_limit=1" in msg
        # the cycle names both the starved gather and the holder, with
        # the directives that introduced them
        assert any("ZeRO" in p for p in d2[0].provenance)
        assert "limiter" in d2[0].details["edge_kinds"]
        assert d2[0].details["cycle"]

    def test_same_mutation_is_caught_without_execution_too(self):
        # the stuck state is reached abstractly — no interpreter, no XLA
        prog = self._fuse_gathers_across_fb(compile_mlp("1f1b", 3))
        outcome = AbstractExecutor(prog, gather_limit=1).run()
        assert isinstance(outcome, StuckState)
        assert outcome.limiter_blocked
        assert outcome.executed < outcome.total


# ---------------------------------------------------------------------------
# scheduler delegation + compiler embedding
# ---------------------------------------------------------------------------

class TestIntegration:
    def test_comm_order_violation_carries_report(self):
        prog = compile_mlp()
        mut = copy.deepcopy(prog)
        for dp in mut.plan.device_plans.values():
            for keys in dp.streams.values():
                colls = [i for i, k in enumerate(keys) if k[2] == "coll"]
                if len(colls) >= 2:
                    i, j = colls[0], colls[1]
                    keys[i], keys[j] = keys[j], keys[i]
                    with pytest.raises(ScheduleRejected,
                                       match="dispatch order") as ei:
                        validate_comm_order(mut.dag, mut.plan)
                    assert isinstance(ei.value, PlanVerificationError)
                    assert "PIPER004" in ei.value.report.codes()
                    return
        raise AssertionError("no stream with two collectives")

    def test_compile_embeds_quick_analysis(self):
        prog = compile_mlp()
        assert prog.stats["analysis"] == {
            "depth": "quick", "diagnostics": 0, "codes": []}
        deep = compile_mlp(analyze="deep")
        assert deep.stats["analysis"]["depth"] == "deep"
        off = compile_mlp(analyze="off")
        assert "analysis" not in off.stats

    def test_compile_rejects_bad_depth(self):
        with pytest.raises(ValueError, match="depth"):
            compile_mlp(analyze="paranoid")

    def test_pass_boundary_check_catches_dangling_edges(self, monkeypatch):
        from repro.core import passes
        monkeypatch.setenv("REPRO_CHECK_PASSES", "1")
        prog = compile_mlp(analyze="off")
        dag = prog.dag
        dag.temporal.add((10 ** 6, next(iter(dag.nodes))))
        with pytest.raises(ValueError, match="pass boundary"):
            passes.run_all(dag)

    def test_diagnostic_codes_are_stable(self):
        # PR 8's scheduling layer (001-011) plus PR 9's semantic layer
        # (020-026); released codes never change meaning
        assert set(CODES) == ({f"PIPER{i:03d}" for i in range(1, 12)}
                              | {f"PIPER{i:03d}" for i in range(20, 27)})


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

class TestLintCLI:
    def test_grid_subset_clean(self, tmp_path, capsys):
        from repro.launch.lint import main
        out = tmp_path / "lint.json"
        rc = main(["--grid", "--arch", "qwen1.5-0.5b",
                   "--json", "--out", str(out)])
        assert rc == 0
        result = json.loads(out.read_text())
        # 6 schedule x ZeRO cells + 3 remat/offload memory cells
        assert result["ok"] and len(result["cells"]) == 9
        assert all(c["codes"] == [] for c in result["cells"])
        assert sum(1 for c in result["cells"]
                   if c["remat"] == "none") == 3
        assert sum(1 for c in result["cells"] if c["offload"]) == 1
        # the semantic layer (typechecker + rank signatures) ran
        assert all(c["meta"]["types"] for c in result["cells"])
        assert json.loads(capsys.readouterr().out)["ok"]

    def test_strategy_file_lints_clean(self, tmp_path, capsys):
        from repro.launch.lint import main
        strat = Strategy(Mesh(pp=2, dp=2),
                         Pipeline("1f1b", n_mb=4) | ZeRO(stage=3))
        f = tmp_path / "strategy.json"
        f.write_text(strat.to_json())
        rc = main(["--strategy", str(f), "--config", "qwen3-1b"])
        assert rc == 0
        assert "0 with errors" in capsys.readouterr().out

    def test_strategy_without_pipeline_is_compile_error(self, tmp_path,
                                                        capsys):
        from repro.launch.lint import main
        # to_json refuses to serialize an invalid strategy, so craft the
        # bad artifact by stripping the Pipeline fragment from a valid one
        strat = Strategy(Mesh(pp=2, dp=2),
                         Pipeline("1f1b", n_mb=4) | ZeRO(stage=3))
        doc = json.loads(strat.to_json())
        doc["fragments"] = [f for f in doc["fragments"]
                            if f.get("kind") != "pipeline"]
        f = tmp_path / "strategy.json"
        f.write_text(json.dumps(doc))
        rc = main(["--strategy", str(f)])
        assert rc == 2
        assert "COMPILE-ERROR" in capsys.readouterr().out
