"""Strategy API (core/strategy.py): named-axis Mesh derivation, fragment
composition/validation, byte-stable JSON round-trips with schema
gating, and the acceptance bar — for every schedule kind the
``compile_training(strategy=...)`` front door produces a GlobalPlan
with per-device per-stream op sequences identical to the legacy
``emit_directives`` + hand-assembled directive-list path."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (ExpertParallel, F, Mesh, Order, Overlap,
                        OverlapConfig, Pipeline, Place, RawDirectives,
                        Replicate, Shard, Split, Strategy, StrategyError,
                        ZeRO, compile_training)
from repro.core.schedules import (build_rank_sequences, emit_directives,
                                  rank_of_stage)
from repro.tune.space import SCHEDULE_KINDS, Candidate, MeshSpec

from helpers import (inputs_spec, make_batch, make_mlp_params,
                     make_moe_forward, mlp_oracle, raw_strategy)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Mesh
# ---------------------------------------------------------------------------

class TestMesh:
    def test_rank_major_groups_match_meshspec(self):
        for pp, dp in ((2, 1), (2, 2), (4, 2), (3, 4)):
            mesh = Mesh(pp=pp, dp=dp)
            assert mesh.device_groups("pp") == \
                MeshSpec(pp=pp, dp=dp).device_groups()
            assert mesh.n_devices == pp * dp

    def test_groups_along_inner_axis(self):
        assert Mesh(pp=2, dp=2).device_groups("dp") == [[0, 2], [1, 3]]

    def test_three_axis_mixed_radix(self):
        mesh = Mesh(pp=2, dp=2, ep=2)
        assert mesh.n_devices == 8
        assert mesh.device_groups("pp") == [[0, 1, 2, 3], [4, 5, 6, 7]]
        assert mesh.device_groups("ep") == [[0, 2, 4, 6], [1, 3, 5, 7]]

    def test_axis_order_is_identity(self):
        assert Mesh(pp=2, dp=4) != Mesh(dp=4, pp=2)
        assert Mesh(pp=2, dp=4) == Mesh(pp=2, dp=4)
        assert hash(Mesh(pp=2, dp=4)) == hash(Mesh(pp=2, dp=4))

    def test_bad_axes_rejected(self):
        with pytest.raises(StrategyError):
            Mesh()
        with pytest.raises(StrategyError):
            Mesh(pp=0)
        with pytest.raises(StrategyError, match="no axis 'tp'"):
            Mesh(pp=2).axis_size("tp")


# ---------------------------------------------------------------------------
# composition + validation
# ---------------------------------------------------------------------------

class TestComposition:
    def test_pipe_operator_builds_fragment_chain(self):
        s = Strategy(Mesh(pp=2, dp=2),
                     Pipeline("1f1b", n_mb=4) | ZeRO(stage=2)
                     | Overlap(prefetch=2, bucket_mb=8))
        assert s.pipeline.schedule == "1f1b"
        assert s.zero.stage == 2
        assert s.overlap.prefetch == 2
        s2 = Strategy(Mesh(pp=2, dp=2), Pipeline("1f1b", n_mb=4)) \
            | ZeRO(stage=2) | Overlap(prefetch=2, bucket_mb=8)
        assert s2 == s

    def test_duplicate_fragment_error_names_fragment(self):
        s = Strategy(Mesh(pp=2), Pipeline("1f1b", n_mb=2)
                     | Pipeline("gpipe", n_mb=4))
        with pytest.raises(StrategyError, match="gpipe.*duplicate"):
            s.validate()

    def test_validation_errors_name_offending_fragment(self):
        cases = [
            (Pipeline("nope", n_mb=2), "unknown schedule"),
            (Pipeline("1f1b", n_mb=0), "n_mb"),
            (Pipeline("1f1b", n_mb=2, axis="tp"), "no axis"),
            (Pipeline("dualpipev", n_mb=2, n_stages=6), "dualpipev"),
            (ZeRO(stage=7), "stage"),
            (ExpertParallel(degree=3), "degree"),
        ]
        for frag, needle in cases:
            strat = (Strategy(Mesh(pp=2, dp=2), frag) if
                     isinstance(frag, Pipeline) else
                     Strategy(Mesh(pp=2, dp=2),
                              Pipeline("1f1b", n_mb=2) | frag))
            with pytest.raises(StrategyError) as ei:
                strat.validate()
            msg = str(ei.value)
            assert "fragment" in msg and needle in msg, msg

    def test_zero_requires_pipeline(self):
        with pytest.raises(StrategyError, match="Pipeline"):
            Strategy(Mesh(pp=2, dp=2), ZeRO(stage=1)).validate()

    def test_raw_does_not_compose_with_structured(self):
        s = Strategy(Mesh(pp=2),
                     Pipeline("1f1b", n_mb=2)
                     | RawDirectives((Split(F(), num_microbatches=2),)))
        with pytest.raises(StrategyError, match="RawDirectives"):
            s.validate()

    def test_split_backward_derivation(self):
        m = Mesh(pp=2)
        assert Strategy(m, Pipeline("dualpipev", n_mb=4)).split_backward
        assert Strategy(m, Pipeline("zb1f1b", n_mb=4)).split_backward
        assert not Strategy(m, Pipeline("1f1b", n_mb=4)).split_backward
        assert Strategy(m, Pipeline("1f1b", n_mb=4,
                                    split_backward=True)).split_backward

    def test_replacing_and_without(self):
        base = Strategy(Mesh(pp=2, dp=2),
                        Pipeline("1f1b", n_mb=4)
                        | Overlap(prefetch=4, bucket_mb=32))
        swapped = base.replacing(Overlap(prefetch=1, bucket_mb=0))
        assert swapped.overlap.prefetch == 1
        assert swapped.pipeline == base.pipeline
        added = base.without(Overlap).replacing(Overlap(prefetch=2,
                                                        bucket_mb=8))
        assert added.overlap.prefetch == 2
        assert base.without(Overlap).overlap is None

    def test_overlap_config_bridge(self):
        ov = Overlap(prefetch=3, bucket_mb=16)
        cfg = ov.to_overlap_config()
        assert cfg.enabled and cfg.prefetch == 3
        assert cfg.bucket_bytes == 16 << 20
        assert Overlap.from_config(cfg) == ov
        off = Overlap.from_config(OverlapConfig.off())
        assert not off.to_overlap_config().enabled


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

def _sample_strategies():
    return [
        Strategy(Mesh(pp=2), Pipeline("gpipe", n_mb=4)),
        Strategy(Mesh(pp=2, dp=2),
                 Pipeline("1f1b", n_mb=8) | ZeRO(stage=3)),
        Strategy(Mesh(pp=2, dp=2),
                 Pipeline("dualpipev", n_mb=8) | ZeRO(stage=2,
                                                      bucket_mb=4)
                 | ExpertParallel() | Overlap(prefetch=4, bucket_mb=32)),
    ]


class TestJson:
    def test_round_trip_byte_stable(self):
        for s in _sample_strategies():
            doc = s.to_json()
            back = Strategy.from_json(doc)
            assert back == s
            assert back.to_json() == doc          # byte-for-byte
            assert Strategy.from_json(back.to_json()).to_json() == doc

    def test_unknown_schema_version_rejected(self):
        from repro.core import SCHEMA_VERSION
        doc = _sample_strategies()[0].to_json()
        cur = f'"schema":{SCHEMA_VERSION}'
        assert cur in doc
        for bad in (f'"schema":{SCHEMA_VERSION - 1}',
                    f'"schema":{SCHEMA_VERSION + 1}',
                    f'"schema":"{SCHEMA_VERSION}"'):
            mutated = doc.replace(cur, bad)
            assert mutated != doc
            with pytest.raises(StrategyError, match="schema version"):
                Strategy.from_json(mutated)

    def test_unknown_fragment_kind_rejected(self):
        doc = _sample_strategies()[1].to_json()
        mutated = doc.replace('"kind":"zero"', '"kind":"fsdp"')
        with pytest.raises(StrategyError, match="unknown fragment kind"):
            Strategy.from_json(mutated)

    def test_unknown_fragment_field_rejected(self):
        doc = _sample_strategies()[0].to_json()
        mutated = doc.replace('"n_mb":4', '"n_mb":4,"warp":9')
        with pytest.raises(StrategyError, match="unknown field"):
            Strategy.from_json(mutated)

    def test_raw_directives_not_serializable(self):
        s = Strategy(None, RawDirectives(()))
        with pytest.raises(StrategyError, match="not serializable|mesh"):
            s.to_json()

    def test_garbage_json_rejected(self):
        with pytest.raises(StrategyError, match="parse"):
            Strategy.from_json("{nope")


# ---------------------------------------------------------------------------
# lowering parity: strategy front door == legacy directive lists
# ---------------------------------------------------------------------------

R, DP, N_MB, BATCH = 2, 2, 4, 16
S = 2 * R


def _moe_params():
    p = make_mlp_params(jax.random.PRNGKey(0), S)
    for i in range(S - 1):
        if i % 2 == 1:
            p[f"exp{i}"] = {"w1": jnp.ones((16, 16)) * .1,
                            "w2": jnp.ones((16, 16)) * .1}
    return p


def _legacy_schedule(kind, zero=3, ep=True):
    groups = [[r * DP + i for i in range(DP)] for r in range(R)]
    seqs = build_rank_sequences(kind, R, N_MB, S)
    sched = emit_directives(kind, seqs, device_groups=groups, n_stages=S)
    extra = []
    for s in range(S):
        g = groups[rank_of_stage(kind, s, R, S)]
        extra.append(Replicate(F(pp=s, ep="-"), devices=g,
                               reduce_stream="dp", gather_stream="ag",
                               shard_grads=zero >= 2,
                               shard_params=zero >= 3))
        if s % 2 == 1 and s < S - 1:
            if ep:
                extra.append(Shard(F(pp=s, ep="*"), devices=g,
                                   stream="ep"))
            else:
                extra.append(Replicate(F(pp=s, ep="*"), devices=g,
                                       reduce_stream="dp",
                                       gather_stream="ag",
                                       shard_grads=zero >= 2,
                                       shard_params=zero >= 3))
    return sched[:S] + extra + sched[S:]


def _device_sequences(prog):
    """Per-device per-stream (name, MB, role) dispatch sequences — node
    ids differ across compiles, so compare structural identity."""
    out = {}
    for dev, p in prog.plan.device_plans.items():
        out[dev] = {
            stream: [(prog.dag.nodes[n].name,
                      prog.dag.nodes[n].dims.get("MB"),
                      prog.dag.nodes[n].dims.get("PASS"), role)
                     for (n, _, role) in keys]
            for stream, keys in p.streams.items()}
    return out


class TestLoweringParity:
    @pytest.mark.parametrize("kind", SCHEDULE_KINDS)
    def test_all_kinds_plan_identical_to_legacy_path(self, kind):
        """Acceptance: for every schedule kind the Strategy path yields
        the same per-device op sequences as the pre-existing
        emit_directives + hand-built Replicate/Shard list."""
        params = _moe_params()
        fwd = make_moe_forward(S)
        legacy = compile_training(
            fwd, params, inputs_spec(BATCH),
            strategy=raw_strategy(
                _legacy_schedule(kind),
                split_backward=kind in ("dualpipev", "zb1f1b")))
        strat = Strategy(Mesh(pp=R, dp=DP),
                         Pipeline(kind, n_mb=N_MB) | ZeRO(stage=3)
                         | ExpertParallel())
        new = compile_training(fwd, params, inputs_spec(BATCH),
                               strategy=strat)
        assert _device_sequences(new) == _device_sequences(legacy)
        assert new.strategy is strat

    def test_replicated_experts_parity(self):
        """ep=1 (no ExpertParallel fragment): experts replicate through
        the ZeRO fragment exactly like the legacy elif branch."""
        params = _moe_params()
        fwd = make_moe_forward(S)
        legacy = compile_training(
            fwd, params, inputs_spec(BATCH),
            strategy=raw_strategy(_legacy_schedule("1f1b", ep=False)))
        strat = Strategy(Mesh(pp=R, dp=DP),
                         Pipeline("1f1b", n_mb=N_MB) | ZeRO(stage=3))
        new = compile_training(fwd, params, inputs_spec(BATCH),
                               strategy=strat)
        assert _device_sequences(new) == _device_sequences(legacy)

    def test_strategy_numerics_match_oracle(self):
        """The strategy front door is not just plan-identical — the
        interpreter reproduces the unscheduled model's loss."""
        from repro.runtime import Interpreter
        from helpers import make_mlp_forward
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        strat = Strategy(Mesh(pp=R), Pipeline("1f1b", n_mb=N_MB))
        prog = compile_training(make_mlp_forward(S), params,
                                inputs_spec(BATCH), strategy=strat)
        batch = make_batch(BATCH)
        res = Interpreter(prog).run(batch)
        l, g = mlp_oracle(params, batch["x"], batch["y"], S)
        assert res.loss == pytest.approx(l, abs=1e-6)

    def test_legacy_schedule_arg_still_works_as_raw_shim(self):
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        from helpers import make_mlp_forward
        with pytest.deprecated_call():
            prog = compile_training(make_mlp_forward(S), params,
                                    inputs_spec(BATCH),
                                    _legacy_schedule("1f1b", ep=False,
                                                     zero=1)[:S + 1])
        assert prog.strategy.raw          # wrapped into RawDirectives

    def test_strategy_and_legacy_args_mutually_exclusive(self):
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        from helpers import make_mlp_forward
        strat = Strategy(Mesh(pp=R), Pipeline("1f1b", n_mb=2))
        with pytest.raises(ValueError, match="not both"):
            compile_training(make_mlp_forward(S), params,
                             inputs_spec(BATCH),
                             schedule=[Split(F(), num_microbatches=2)],
                             strategy=strat)


# ---------------------------------------------------------------------------
# satellite: actionable errors
# ---------------------------------------------------------------------------

class TestDirectiveErrors:
    def _dense_prog_dag(self):
        from repro.core.autodiff import build_backward
        from repro.core.trace import Recorder
        from helpers import make_mlp_forward
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        rec = Recorder(params)
        tvs = {name: rec.input(name, shape, dtype)
               for name, (shape, dtype) in inputs_spec(BATCH).items()}
        loss = make_mlp_forward(S)(rec, tvs)
        dag = rec.finalize(loss)
        build_backward(dag)
        return dag

    def test_place_no_match_lists_dims_and_nearest_nodes(self):
        dag = self._dense_prog_dag()
        with pytest.raises(ValueError) as ei:
            Place(F(pq=99), devices=[0]).apply(dag)
        msg = str(ei.value)
        assert "Available dims" in msg and "pp" in msg
        assert "Nearest nodes" in msg and "s0" in msg

    def test_order_no_match_reports(self):
        dag = self._dense_prog_dag()
        with pytest.raises(ValueError, match="Available dims"):
            Order([F(pp=123)]).apply(dag)

    def test_shard_no_match_reports_chunks(self):
        dag = self._dense_prog_dag()
        with pytest.raises(ValueError, match="matched no chunks"):
            Shard(F(ep="*"), devices=[0]).apply(dag)

    def test_order_before_split_footgun_raises(self):
        """Legacy path: an Order with overlap groups issued before the
        Split that clones its nodes used to silently drop the groups —
        now a loud ValueError."""
        from helpers import make_mlp_forward
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        bad = [Place(F(pp=s), devices=[0]) for s in range(S)] + [
            Order([[F(pp=0, PASS="F"), F(pp=1, PASS="F")]],),
            Split(F(), num_microbatches=2),
        ]
        with pytest.raises(ValueError, match="Order after Split|after"):
            compile_training(make_mlp_forward(S), params,
                             inputs_spec(BATCH),
                             strategy=raw_strategy(bad))


# ---------------------------------------------------------------------------
# Candidate <-> Strategy bridge (the tuner speaks the same dialect)
# ---------------------------------------------------------------------------

class TestCandidateBridge:
    def test_round_trip_through_strategy(self):
        mesh = MeshSpec(pp=2, dp=2)
        for cand in (Candidate("1f1b", n_mb=4),
                     Candidate("dualpipev", n_mb=8, zero=3, ep=2,
                               prefetch=4, bucket_mb=16),
                     Candidate("gpipe", n_mb=4, zero=1)):
            s = cand.to_strategy(mesh)
            assert Candidate.from_strategy(s) == cand
            # and the strategy document round-trips byte-stably too
            assert Strategy.from_json(s.to_json()) == s

    def test_candidate_strategy_compiles_like_directives(self):
        """tune.build_candidate_program (Strategy path) matches the
        lowered candidate_directives list applied by hand."""
        from repro.configs import get_config
        from repro.tune import build_candidate_program, candidate_directives
        from repro.tune.proxy import (make_proxy_forward,
                                      make_proxy_params)
        cfg = get_config("qwen3-1b")
        mesh = MeshSpec(pp=2, dp=2)
        cand = Candidate("1f1b", n_mb=4, zero=3)
        tokens = 4096
        prog, sm = build_candidate_program(cfg, mesh, cand, tokens)
        sched = candidate_directives(cfg, mesh, cand, sm)
        legacy = compile_training(
            make_proxy_forward(sm), make_proxy_params(sm),
            {"x": ((tokens, sm.d_model), "bfloat16"),
             "y": ((tokens, sm.d_model), "bfloat16")},
            strategy=raw_strategy(sched))
        assert _device_sequences(prog) == _device_sequences(legacy)
