"""Shared test helpers: tiny annotated models + oracles."""
import jax
import jax.numpy as jnp

from repro.core import Overlap, RawDirectives, Strategy

D = 16


def raw_strategy(sched, split_backward=False, overlap=None):
    """Wrap a hand-assembled directive list for the ``strategy=`` front
    door — the supported spelling of what tests used to pass through the
    deprecated ``compile_training(schedule=...)`` keyword.  ``overlap``
    takes an ``OverlapConfig`` (or None for the legacy no-engine
    plan)."""
    frags = RawDirectives(tuple(sched), split_backward=split_backward)
    if overlap is not None:
        frags = frags | Overlap.from_config(overlap)
    return Strategy(None, frags)


def stage_fn(p, x):
    h = jnp.tanh(x @ p["w1"])
    return jnp.tanh(h @ p["w2"])


def loss_fn(p, x, y):
    return jnp.mean((stage_fn(p, x) - y) ** 2)


def make_mlp_params(key, n_stage, d=D):
    ks = jax.random.split(key, 2 * n_stage)
    return {f"stage{i}": {
        "w1": jax.random.normal(ks[2 * i], (d, d)) * 0.1,
        "w2": jax.random.normal(ks[2 * i + 1], (d, d)) * 0.1,
    } for i in range(n_stage)}


def make_mlp_forward(n_stage):
    """n_stage PP-annotated stages; the last one computes the loss."""
    def forward(rec, tvs):
        h = tvs["x"]
        for i in range(n_stage - 1):
            with rec.annotate("pp"):
                h = rec.region(stage_fn, f"stage{i}", name=f"s{i}")(h)
        with rec.annotate("pp"):
            loss = rec.region(loss_fn, f"stage{n_stage-1}",
                              name="head")(h, tvs["y"])
        return loss
    return forward


def make_moe_forward(n_stage, experts_every=2):
    """PP stages with an EP-annotated expert region every k-th stage."""
    def forward(rec, tvs):
        h = tvs["x"]
        for i in range(n_stage - 1):
            with rec.annotate("pp"):
                h = rec.region(stage_fn, f"stage{i}", name=f"s{i}")(h)
                if i % experts_every == 1:
                    with rec.annotate("ep"):
                        h = rec.region(stage_fn, f"exp{i}",
                                       name=f"e{i}")(h)
        with rec.annotate("pp"):
            loss = rec.region(loss_fn, f"stage{n_stage-1}",
                              name="head")(h, tvs["y"])
        return loss
    return forward


def mlp_oracle(params, x, y, n_stage, expert_stages=()):
    def full(params):
        h = x
        for i in range(n_stage - 1):
            h = stage_fn(params[f"stage{i}"], h)
            if i in expert_stages:
                h = stage_fn(params[f"exp{i}"], h)
        return loss_fn(params[f"stage{n_stage-1}"], h, y)
    l, g = jax.value_and_grad(full)(params)
    return float(l), g


def make_batch(batch=8, d=D, seed=1):
    x = jax.random.normal(jax.random.PRNGKey(seed), (batch, d))
    y = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, d))
    return {"x": x, "y": y}


def inputs_spec(batch=8, d=D):
    return {"x": ((batch, d), "float32"), "y": ((batch, d), "float32")}


def assert_grads_close(got, want, atol=1e-5):
    import numpy as np
    for bucket in want:
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(a, b, atol=atol,
                                                    rtol=1e-4),
            got[bucket], want[bucket])


def run_child_once_retry(child_src, arg, timeout=600, retries=1):
    """Run a ``python -c`` child (PYTHONPATH=src:tests, JAX on CPU) and
    return its stdout, retrying once on a non-zero exit: the faked-host
    XLA device grids occasionally hit a flaky backend startup, and one
    retry must not red-flag the suite.  A child that fails twice is a
    real failure and raises with both transcripts."""
    import os
    import pathlib
    import subprocess
    import sys
    root = pathlib.Path(__file__).resolve().parent.parent
    env = {**os.environ, "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": f"{root / 'src'}{os.pathsep}{root / 'tests'}"}
    attempts = []
    for _ in range(retries + 1):
        proc = subprocess.run(
            [sys.executable, "-c", child_src, arg],
            capture_output=True, text=True, timeout=timeout, env=env)
        if proc.returncode == 0:
            return proc.stdout
        attempts.append(proc)
    raise AssertionError(
        f"child failed on all {len(attempts)} attempt(s):\n" + "\n".join(
            f"--- attempt {i + 1} (rc={p.returncode}) ---\n"
            f"{p.stdout}\n{p.stderr}" for i, p in enumerate(attempts)))
